"""Round-engine benchmark: sequential loop vs vmap/scan vs sharded cohorts.

Measures steady-state wall-clock per federated round at growing cohort
sizes. The model is deliberately tiny (1 layer, d=32, batch 1×8 tokens):
the engines run IDENTICAL numerics, so the only thing this sweep can
show is orchestration cost — per-client jit dispatch in the sequential
loop, one stacked ``vmap`` dispatch per cohort, or the stacked cohort
partitioned over a ``("clients",)`` device mesh with the host-side
stack/unstack double-buffered behind device compute.

Timing protocol (per size × engine):

  1. warmup run (``rounds=1``) — pays compilation, discarded
  2. ``T1`` = wall of a fresh ``rounds=1`` run
  3. ``T3`` = wall of a fresh ``rounds=3`` run
  4. ``per_round = (T3 - T1) / 2`` — client init, round-1 host→device
     conversion, and data setup subtract out; what remains is the
     steady-state cost of one round.

At the largest size the cohort is folded through the streaming merge
(``agg_chunk``) for BOTH engines, bounding server memory and vmap
compile width while keeping the comparison apples-to-apples.

Usage:
    PYTHONPATH=src python benchmarks/engine_bench.py            # full sweep
    PYTHONPATH=src python benchmarks/engine_bench.py --quick    # ~10 s wiring check
    PYTHONPATH=src python benchmarks/engine_bench.py --sizes 10000
    PYTHONPATH=src python benchmarks/engine_bench.py --devices 8 --sizes 1000 \
        --label "PR10 sharded engine"    # sharded vs vmap on an 8-device mesh

``--devices N`` forces an N-device CPU topology (the flag is parsed before
jax initializes, so no XLA_FLAGS exporting needed) and benches
``engine="sharded"`` with the double buffer on AND off against the vmap
baseline, recording client-init ``setup_s`` per engine.

Full runs merge results into BENCH_engine.json at the repo root, keyed by
(clients, devices) — existing entries for a re-run key are replaced,
other keys are preserved.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))


def _early_devices(argv):
    """Pull --devices out of argv BEFORE the first jax import: the forced
    host-platform device count only takes effect if XLA_FLAGS is set before
    the backend initializes."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return None


_DEVICES = _early_devices(sys.argv[1:]) if __name__ == "__main__" else None
if _DEVICES and _DEVICES > 1:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={_DEVICES}".strip())

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT = os.path.join(ROOT, "BENCH_engine.json")

STRATEGY = "fednano"
ROUNDS_SHORT, ROUNDS_LONG = 1, 3

SHARDED_MECHANISM = (
    "shard_map over a 1-D ('clients',) mesh, cohort split into cache-sized "
    "chunks (width capped at 128: the per-client step cost of one huge "
    "program is ~35-50% worse once the stacked working set falls out of "
    "cache); chunk state is device-resident across rounds (last round's "
    "stacked AdamW/adapter/Fisher outputs feed the next dispatch and the "
    "merge directly, skipping the per-round gather + restack), per-chunk "
    "batch stacks are cached, and aggregation runs device-side: stacked "
    "chunk outputs fold into the Fisher merge in one fused dispatch per "
    "round with padding rows masked by zero weight, losses gathered in one "
    "batched device_get — the host marshalling and per-chunk collective "
    "barriers that dominate vmap rounds at large K are all eliminated; the "
    "two-deep double buffer prepares cohort k+1 while cohort k computes")


def bench_setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train1, _, _ = make_federated_data(
        cfg, n_clients=1, examples_per_client=2, alpha=1.0, batch_size=1,
        seq_len=8, seed=0,
    )
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=1)
    return cfg, train1[0], hp


def _wall(cfg, shared_batches, hp, *, clients, engine, rounds, agg_chunk,
          **engine_kw):
    # every client references the SAME batch list object: the engine's
    # shared-data fast path broadcasts it instead of stacking K copies
    train = {cid: shared_batches for cid in range(clients)}
    evald = {cid: shared_batches for cid in range(clients)}
    t0 = time.time()
    res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                        strategy=STRATEGY, rounds=rounds, hp=hp,
                        engine=engine, agg_chunk=agg_chunk, final_eval=False,
                        **engine_kw)
    return time.time() - t0, res.setup_s


def _time_engine(cfg, shared, hp, row, clients, engine, agg_chunk, *,
                 prefix=None, **engine_kw):
    """Warmup + T1/T3 protocol for one engine; writes ``<prefix>_*`` keys."""
    prefix = prefix or engine
    kw = dict(clients=clients, engine=engine, agg_chunk=agg_chunk, **engine_kw)
    _wall(cfg, shared, hp, rounds=ROUNDS_SHORT, **kw)  # compile warmup
    t1, _ = _wall(cfg, shared, hp, rounds=ROUNDS_SHORT, **kw)
    t3, setup_s = _wall(cfg, shared, hp, rounds=ROUNDS_LONG, **kw)
    row[f"{prefix}_t1_s"] = round(t1, 4)
    row[f"{prefix}_t3_s"] = round(t3, 4)
    row[f"{prefix}_per_round_s"] = round(
        (t3 - t1) / (ROUNDS_LONG - ROUNDS_SHORT), 4)
    return setup_s


def bench_size(cfg, shared, hp, clients, *, agg_chunk=None):
    row = {"clients": clients, "strategy": STRATEGY, "agg_chunk": agg_chunk}
    for engine in ("sequential", "vmap"):
        setup_s = _time_engine(cfg, shared, hp, row, clients, engine, agg_chunk)
    row["setup_s"] = round(setup_s, 4)
    row["speedup"] = round(
        row["sequential_per_round_s"] / max(row["vmap_per_round_s"], 1e-9), 2)
    print(f"  K={clients:>6}  seq/round={row['sequential_per_round_s']:8.3f}s  "
          f"vmap/round={row['vmap_per_round_s']:8.3f}s  "
          f"speedup={row['speedup']:.2f}x"
          + (f"  (agg_chunk={agg_chunk})" if agg_chunk else ""))
    return row


def bench_size_sharded(cfg, shared, hp, clients, devices, *, agg_chunk=None,
                       label=""):
    """Sharded (overlap on AND off) vs the vmap baseline on one mesh size.

    ``agg_chunk`` applies to the vmap baseline only (it bounds vmap's
    compile width and server memory at huge cohorts — strictly in vmap's
    favor); the sharded engine picks its own cache-sized dispatch width and
    folds device-side, so forcing a dispatch width through ``agg_chunk``
    would bench a hobbled configuration rather than the engine."""
    row = {"clients": clients, "devices": devices, "strategy": STRATEGY,
           "agg_chunk": agg_chunk, "sharded_agg_chunk": None, "label": label,
           "mechanism": SHARDED_MECHANISM}
    _time_engine(cfg, shared, hp, row, clients, "vmap", agg_chunk)
    setup_s = _time_engine(
        cfg, shared, hp, row, clients, "sharded", None,
        prefix="sharded", devices=devices, overlap=True)
    _time_engine(
        cfg, shared, hp, row, clients, "sharded", None,
        prefix="sharded_no_overlap", devices=devices, overlap=False)
    row["setup_s"] = round(setup_s, 4)
    row["speedup"] = round(
        row["vmap_per_round_s"] / max(row["sharded_per_round_s"], 1e-9), 2)
    row["overlap_gain"] = round(
        row["sharded_no_overlap_per_round_s"]
        / max(row["sharded_per_round_s"], 1e-9), 2)
    print(f"  K={clients:>6} D={devices}  "
          f"vmap/round={row['vmap_per_round_s']:8.3f}s  "
          f"sharded/round={row['sharded_per_round_s']:8.3f}s "
          f"(no-overlap {row['sharded_no_overlap_per_round_s']:.3f}s)  "
          f"speedup={row['speedup']:.2f}x  setup={row['setup_s']:.3f}s"
          + (f"  (agg_chunk={agg_chunk})" if agg_chunk else ""))
    return row


def _row_key(r):
    return (r["clients"], r.get("devices", 1))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default=None,
                    help="comma-separated cohort sizes (default 10,100,1000,10000)")
    ap.add_argument("--devices", type=int, default=None,
                    help="bench engine='sharded' on an N-device mesh "
                         "(forces the CPU topology before jax init)")
    ap.add_argument("--label", default="",
                    help="free-form label stamped on sharded rows")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes, no JSON written — wiring check for smoke runs")
    ap.add_argument("--out", default=None,
                    help=f"output JSON (default {OUT}; --quick skips writing)")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = [int(s) for s in args.sizes.split(",")]
    elif args.quick:
        sizes = [4, 8]
    else:
        sizes = [10, 100, 1000, 10000]

    if args.devices and args.devices > jax.device_count():
        ap.error(f"--devices {args.devices} but only {jax.device_count()} "
                 "visible; pass --devices on the command line (not via "
                 "main(argv)) so the topology is forced before jax init")

    cfg, shared, hp = bench_setup()
    print(f"### engine bench: {STRATEGY}, local_steps={hp.local_steps}, "
          f"per_round = (T(rounds={ROUNDS_LONG}) - T(rounds={ROUNDS_SHORT}))/"
          f"{ROUNDS_LONG - ROUNDS_SHORT}"
          + (f", devices={args.devices}" if args.devices else ""))
    rows = []
    for k in sizes:
        # at huge cohorts, stream-fold chunks: O(chunk) server memory and a
        # bounded vmap compile width, identically for both engines
        chunk = 1000 if k > 1000 else None
        if args.devices and args.devices > 1:
            rows.append(bench_size_sharded(
                cfg, shared, hp, k, args.devices, agg_chunk=chunk,
                label=args.label))
        else:
            rows.append(bench_size(cfg, shared, hp, k, agg_chunk=chunk))

    out_path = args.out or (None if args.quick else OUT)
    if out_path:
        doc = {"config": {
            "arch": "llava-1.5-7b (reduced: 1 layer, d_model=32, d_ff=64)",
            "strategy": STRATEGY, "local_steps": hp.local_steps,
            "fisher_batches": hp.fisher_batches, "batch_size": 1, "seq_len": 8,
            "timing": f"per_round = (T(rounds={ROUNDS_LONG}) - "
                      f"T(rounds={ROUNDS_SHORT}))/{ROUNDS_LONG - ROUNDS_SHORT}, "
                      "fresh seeded run each, after a compile warmup run",
        }, "results": []}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    doc["results"] = json.load(f).get("results", [])
            except (json.JSONDecodeError, OSError):
                pass
        done = {_row_key(r) for r in rows}
        doc["results"] = sorted(
            [r for r in doc["results"] if _row_key(r) not in done] + rows,
            key=_row_key)
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
