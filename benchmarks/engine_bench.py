"""Round-engine benchmark: sequential Python loop vs vmap/scan cohorts.

Measures steady-state wall-clock per federated round at growing cohort
sizes. The model is deliberately tiny (1 layer, d=32, batch 1×8 tokens):
the engines run IDENTICAL numerics, so the only thing this sweep can
show is orchestration cost — per-client jit dispatch in the sequential
loop vs one stacked ``vmap`` dispatch per cohort.

Timing protocol (per size × engine):

  1. warmup run (``rounds=1``) — pays compilation, discarded
  2. ``T1`` = wall of a fresh ``rounds=1`` run
  3. ``T3`` = wall of a fresh ``rounds=3`` run
  4. ``per_round = (T3 - T1) / 2`` — client init, round-1 host→device
     conversion, and data setup subtract out; what remains is the
     steady-state cost of one round.

At the largest size the cohort is folded through the streaming merge
(``agg_chunk``) for BOTH engines, bounding server memory and vmap
compile width while keeping the comparison apples-to-apples.

Usage:
    PYTHONPATH=src python benchmarks/engine_bench.py            # full sweep
    PYTHONPATH=src python benchmarks/engine_bench.py --quick    # ~10 s wiring check
    PYTHONPATH=src python benchmarks/engine_bench.py --sizes 10000

Full runs merge results into BENCH_engine.json at the repo root (existing
entries for re-run sizes are replaced).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT = os.path.join(ROOT, "BENCH_engine.json")

STRATEGY = "fednano"
ROUNDS_SHORT, ROUNDS_LONG = 1, 3


def bench_setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train1, _, _ = make_federated_data(
        cfg, n_clients=1, examples_per_client=2, alpha=1.0, batch_size=1,
        seq_len=8, seed=0,
    )
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=1)
    return cfg, train1[0], hp


def _wall(cfg, shared_batches, hp, *, clients, engine, rounds, agg_chunk):
    # every client references the SAME batch list object: the engine's
    # shared-data fast path broadcasts it instead of stacking K copies
    train = {cid: shared_batches for cid in range(clients)}
    evald = {cid: shared_batches for cid in range(clients)}
    t0 = time.time()
    run_federated(jax.random.PRNGKey(0), cfg, train, evald, strategy=STRATEGY,
                  rounds=rounds, hp=hp, engine=engine, agg_chunk=agg_chunk,
                  final_eval=False)
    return time.time() - t0


def bench_size(cfg, shared, hp, clients, *, agg_chunk=None):
    row = {"clients": clients, "strategy": STRATEGY, "agg_chunk": agg_chunk}
    for engine in ("sequential", "vmap"):
        kw = dict(clients=clients, engine=engine, agg_chunk=agg_chunk)
        _wall(cfg, shared, hp, rounds=ROUNDS_SHORT, **kw)  # compile warmup
        t1 = _wall(cfg, shared, hp, rounds=ROUNDS_SHORT, **kw)
        t3 = _wall(cfg, shared, hp, rounds=ROUNDS_LONG, **kw)
        per_round = (t3 - t1) / (ROUNDS_LONG - ROUNDS_SHORT)
        row[f"{engine}_t1_s"] = round(t1, 4)
        row[f"{engine}_t3_s"] = round(t3, 4)
        row[f"{engine}_per_round_s"] = round(per_round, 4)
    row["speedup"] = round(
        row["sequential_per_round_s"] / max(row["vmap_per_round_s"], 1e-9), 2)
    print(f"  K={clients:>6}  seq/round={row['sequential_per_round_s']:8.3f}s  "
          f"vmap/round={row['vmap_per_round_s']:8.3f}s  "
          f"speedup={row['speedup']:.2f}x"
          + (f"  (agg_chunk={agg_chunk})" if agg_chunk else ""))
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default=None,
                    help="comma-separated cohort sizes (default 10,100,1000,10000)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizes, no JSON written — wiring check for smoke runs")
    ap.add_argument("--out", default=None,
                    help=f"output JSON (default {OUT}; --quick skips writing)")
    args = ap.parse_args(argv)

    if args.sizes:
        sizes = [int(s) for s in args.sizes.split(",")]
    elif args.quick:
        sizes = [4, 8]
    else:
        sizes = [10, 100, 1000, 10000]

    cfg, shared, hp = bench_setup()
    print(f"### engine bench: {STRATEGY}, local_steps={hp.local_steps}, "
          f"per_round = (T(rounds={ROUNDS_LONG}) - T(rounds={ROUNDS_SHORT}))/"
          f"{ROUNDS_LONG - ROUNDS_SHORT}")
    rows = []
    for k in sizes:
        # at huge cohorts, stream-fold chunks: O(chunk) server memory and a
        # bounded vmap compile width, identically for both engines
        chunk = 1000 if k > 1000 else None
        rows.append(bench_size(cfg, shared, hp, k, agg_chunk=chunk))

    out_path = args.out or (None if args.quick else OUT)
    if out_path:
        doc = {"config": {
            "arch": "llava-1.5-7b (reduced: 1 layer, d_model=32, d_ff=64)",
            "strategy": STRATEGY, "local_steps": hp.local_steps,
            "fisher_batches": hp.fisher_batches, "batch_size": 1, "seq_len": 8,
            "timing": f"per_round = (T(rounds={ROUNDS_LONG}) - "
                      f"T(rounds={ROUNDS_SHORT}))/{ROUNDS_LONG - ROUNDS_SHORT}, "
                      "fresh seeded run each, after a compile warmup run",
        }, "results": []}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    doc["results"] = json.load(f).get("results", [])
            except (json.JSONDecodeError, OSError):
                pass
        done = {r["clients"] for r in rows}
        doc["results"] = sorted(
            [r for r in doc["results"] if r["clients"] not in done] + rows,
            key=lambda r: r["clients"])
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
