"""Benchmark harness — one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick mode (CI-scale)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale trends

Prints human-readable tables per benchmark followed by a machine-readable
``name,us_per_call,derived`` CSV block (one line per measured cell).
"""
from __future__ import annotations

import argparse
import sys
import time


def _rows(mod, quick):
    out = mod.run(quick=quick)
    norm = []
    for r in out or []:
        if isinstance(r, str):
            norm.append(r)
        else:
            name, wall, derived = r
            norm.append(f"{name},{wall*1e6:.0f},{derived}")
    return norm


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale (slower)")
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (
        ext_beyond,
        fig3a_commfreq,
        fig3b_rank,
        kernel_bench,
        roofline_table,
        table1_efficiency,
        table2_main,
        table3_heterogeneity,
        table4_clients10,
        table5_crosstask,
        table6_adapters,
        table7_ef,
    )

    mods = {
        "table1": table1_efficiency,
        "table2": table2_main,
        "table3": table3_heterogeneity,
        "table4": table4_clients10,
        "table5": table5_crosstask,
        "table6": table6_adapters,
        "table7": table7_ef,
        "fig3a": fig3a_commfreq,
        "fig3b": fig3b_rank,
        "kernels": kernel_bench,
        "ext": ext_beyond,
        "roofline": roofline_table,
    }
    if args.only:
        mods = {args.only: mods[args.only]}

    all_rows = []
    t0 = time.time()
    for name, mod in mods.items():
        t1 = time.time()
        try:
            all_rows.extend(_rows(mod, quick))
        except Exception as e:  # keep the harness running; report the failure
            print(f"[bench {name}] FAILED: {type(e).__name__}: {e}", file=sys.stderr)
            all_rows.append(f"{name}/FAILED,0,{type(e).__name__}")
        print(f"    [{name} done in {time.time()-t1:.1f}s]")

    print(f"\n==== CSV (name,us_per_call,derived) — total {time.time()-t0:.1f}s ====")
    for row in all_rows:
        print(row)


if __name__ == "__main__":
    main()
