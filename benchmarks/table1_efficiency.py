"""Tab. 1 — parameter distribution & communication efficiency (EXACT).

Computed analytically from the real LLaVA-1.5-7B config (no simulation):
client params, per-round uploads, and the reductions vs FedDPA-F-style
PEFT FL with rank-64 adapters inside the LLM.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.comm import adapter_upload_params, client_storage_params


def run(quick: bool = True):
    cfg = get_config("llava-1.5-7b")
    s = client_storage_params(cfg)
    up_nano = adapter_upload_params(cfg)
    up_peft = s["uploads_peft_rank64"]
    total_model = s["backbone_total"] + s["encoder"] + s["connector"]

    client_red = 1 - s["fednano_client_total"] / s["peft_client_total"]
    upload_red = 1 - up_nano / up_peft

    print("\n### Table 1 — parameter & communication efficiency (LLaVA-1.5-7B, rank 64)")
    print(f"{'approach':<12}{'client params':>18}{'share':>9}{'uploads/round':>16}{'share':>9}")
    print(f"{'FedNano':<12}{s['fednano_client_total']/1e6:>15.2f}M"
          f"{100*s['fednano_client_total']/s['peft_client_total']:>8.2f}%"
          f"{up_nano/1e6:>14.2f}M{100*up_nano/total_model:>8.3f}%")
    print(f"{'FedDPA-F':<12}{s['peft_client_total']/1e6:>15.2f}M{100.0:>8.2f}%"
          f"{up_peft/1e6:>14.2f}M{100*up_peft/total_model:>8.3f}%")
    print(f"{'reduction':<12}{100*client_red:>15.1f}%{'':>9}{100*upload_red:>13.1f}%")
    print(f"paper claims: client ↓95.7%, uploads ↓99.4%, uploads ≈1.05M (ours: {up_nano/1e6:.2f}M)")

    rows = [
        ("table1/fednano_uploads_M", 0.0, f"{up_nano/1e6:.3f}"),
        ("table1/client_reduction_pct", 0.0, f"{100*client_red:.1f}"),
        ("table1/upload_reduction_pct", 0.0, f"{100*upload_red:.1f}"),
    ]
    return rows


if __name__ == "__main__":
    run()
