"""Tab. 2 — main comparison: Centralized / LocFT / FedAvg / FedProx /
FedDPA-F / FedNano on both backbones (trend-level, synthetic non-IID corpus).

Paper claim validated: FL > LocFT and FedNano has the best FL average on
both backbones; Centralized is the upper bound.
"""
from __future__ import annotations

from benchmarks.common import csv_row, print_table, run_strategy

STRATEGIES = ["centralized", "locft", "fedavg", "fedprox", "feddpa_f", "fednano"]


def run(quick: bool = True):
    rows_csv = []
    backbones = ["minigpt4"] if quick else ["minigpt4", "llava"]
    rounds = 4 if quick else 6
    for bk in backbones:
        rows = []
        for strat in STRATEGIES:
            res, dt = run_strategy(bk, strat, rounds=rounds, seed=0)
            rows.append((strat, res))
            rows_csv.append(csv_row(f"table2/{bk}/{strat}", dt, f"{res['avg_accuracy']:.4f}"))
        print_table(f"Table 2 — {bk} (synthetic ScienceQA-like, α=1, 5 clients)", rows)
        accs = {n: r["avg_accuracy"] for n, r in rows}
        fl = {k: v for k, v in accs.items() if k not in ("centralized", "locft")}
        best_fl = max(fl, key=fl.get)
        print(f"    best FL strategy: {best_fl} ({100*fl[best_fl]:.2f}) | "
              f"LocFT {100*accs['locft']:.2f} | centralized {100*accs['centralized']:.2f}")
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
