"""Shared benchmark scaffolding.

Every paper-table benchmark runs the REAL federated protocol on the
synthetic non-IID corpus with a reduced backbone (DESIGN.md §6: trend-level
validation — orderings and deltas, not absolute accuracies). All runs are
deterministic in the seed; per-table results are printed as a small table
AND returned as CSV rows ``name,us_per_call,derived`` for benchmarks.run.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_centralized, run_federated
from repro.data import make_federated_data

# the two "backbones" of the paper, reduced to bench scale
BACKBONES = {
    "minigpt4": "minigpt4-7b",
    "llava": "llava-1.5-7b",
}


def bench_config(arch: str, **overrides):
    cfg = get_smoke_config(arch)
    kw = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
              d_ff=256)
    if cfg.frontend_dim:
        kw["frontend_dim"] = 64
    kw.update(overrides)
    return cfg.with_(**kw)


def run_strategy(
    arch_key: str,
    strategy: str,
    *,
    clients: int = 5,
    rounds: int = 4,
    local_steps: int = 10,
    alpha: float = 1.0,
    lr: float = 1e-2,
    seed: int = 0,
    examples_per_client: int = 32,
    seq_len: int = 24,
    batch_size: int = 8,
    rank: int | None = None,
    modalities: Tuple[str, ...] | None = None,
    task_ids: List[int] | None = None,
    transforms=None,
    server_opt=None,
    sampler=None,
) -> Tuple[Dict, float]:
    """Run one (backbone × strategy) cell; returns (result dict, wall seconds).

    ``strategy`` is a registered name or a ``repro.strategies.Strategy``
    instance; ``transforms``/``server_opt``/``sampler`` pass through to the
    engine, so beyond-paper cells (sparsified uploads, FedAdam server, partial
    participation) reuse this scaffolding unchanged.
    """
    import dataclasses

    cfg = bench_config(BACKBONES.get(arch_key, arch_key))
    acfg = cfg.adapter
    if rank is not None:
        acfg = dataclasses.replace(acfg, rank=rank, alpha=2.0 * rank)
    if modalities is not None:
        acfg = dataclasses.replace(acfg, modalities=modalities)
    cfg = cfg.with_(adapter=acfg)

    if task_ids:  # cross-task setup (Tab. 5): one synthetic task per client
        train, evald = {}, {}
        for cid, tid in enumerate(task_ids):
            t, e, _ = make_federated_data(
                cfg, n_clients=1, examples_per_client=examples_per_client,
                alpha=alpha, batch_size=batch_size, seq_len=seq_len,
                seed=seed + tid, task_id=tid,
            )
            train[cid], evald[cid] = t[0], e[0]
    else:
        train, evald, _ = make_federated_data(
            cfg, n_clients=clients, examples_per_client=examples_per_client,
            alpha=alpha, batch_size=batch_size, seq_len=seq_len, seed=seed,
        )

    hp = HyperParams(lr=lr, local_steps=local_steps, fisher_batches=2)
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    if strategy == "centralized":
        res = run_centralized(key, cfg, train, evald,
                              steps=rounds * local_steps * len(train), hp=hp)
    else:
        res = run_federated(key, cfg, train, evald, strategy=strategy,
                            rounds=rounds, hp=hp, transforms=transforms,
                            server_opt=server_opt, sampler=sampler)
    dt = time.time() - t0
    out = {
        "avg_accuracy": res.avg_accuracy,
        "client_accuracy": res.client_accuracy,
        "comm_totals": res.comm_totals,
        "final_loss": res.round_metrics[-1]["mean_loss"] if res.round_metrics else None,
    }
    return out, dt


def csv_row(name: str, wall_s: float, derived) -> str:
    us = wall_s * 1e6
    return f"{name},{us:.0f},{derived}"


def print_table(title: str, rows: List[Tuple[str, Dict]]):
    print(f"\n### {title}")
    cids = sorted(next(iter(rows))[1]["client_accuracy"]) if rows else []
    header = "approach".ljust(14) + "".join(f"C{c+1:<7}" for c in cids) + "avg"
    print(header)
    for name, r in rows:
        cells = "".join(f"{100*r['client_accuracy'][c]:<8.2f}" for c in cids)
        print(f"{name:<14}{cells}{100*r['avg_accuracy']:.2f}")
