"""Tab. 5 — cross-task client distribution: each of 4 clients holds a
DIFFERENT synthetic task (A-OKVQA/OK-VQA/IconQA/GQA analogues = distinct
task_ids with shifted answer mappings and clusters).

Paper claim validated: FedNano stays best on average under task-level
heterogeneity (FedAvg degrades hardest).
"""
from __future__ import annotations

from benchmarks.common import csv_row, print_table, run_strategy

STRATS = ["fedavg", "fedprox", "feddpa_f", "fednano"]


def run(quick: bool = True):
    rows_csv, rows = [], []
    for strat in STRATS:
        res, dt = run_strategy("minigpt4", strat, task_ids=[0, 1, 2, 3],
                               rounds=4, seed=3)
        rows.append((strat, res))
        rows_csv.append(csv_row(f"table5/crosstask/{strat}", dt, f"{res['avg_accuracy']:.4f}"))
    print_table("Table 5 — cross-task federated setup (4 distinct tasks)", rows)
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
