"""Serving benchmark: continuous-batching engine vs naive per-request loop.

Measures mixed-tenant decode throughput (tokens/s) at growing tenant
counts. The model is smoke-scale (h2o-danube, d=256, 2 layers), so
absolute tok/s is meaningless — what the sweep shows is the
*orchestration* win: the naive loop runs one B=1 jitted decode step per
token with a host-Python adapter apply between steps, while the engine
amortizes one fixed-shape batched step over all occupied slots and folds
the per-tenant adapter math into the same jit (grouped LoRA).

Timing protocol (per tenant count):

  1. warmup run of the FULL workload for both paths — pays every
     compilation (the naive loop compiles one prefill per distinct prompt
     length; the engine exactly one prefill + one decode shape), discarded
  2. timed fresh run of the identical workload; throughput = total
     generated tokens / wall

Token parity between the two paths is asserted on every run — a bench
that drifts from the exactness contract is a bug, not a result.

Usage:
    PYTHONPATH=src python benchmarks/serve_bench.py           # full sweep
    PYTHONPATH=src python benchmarks/serve_bench.py --quick   # wiring check
    PYTHONPATH=src python benchmarks/serve_bench.py --tenants 8

Full runs merge results into BENCH_serve.json at the repo root (existing
entries for re-run tenant counts are replaced).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax

from repro.configs import get_smoke_config
from repro.launch.serve import make_requests, synth_tenant_adapters
from repro.models import model as model_lib
from repro.serving import ServingEngine, generate_naive

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT = os.path.join(ROOT, "BENCH_serve.json")

ARCH = "h2o-danube-1.8b"
SLOTS = 8
PREFILL_LEN = 16
GEN_TOKENS = 16


def bench_tenants(cfg, backbone, n_tenants, n_requests, gen_tokens):
    tenants = [f"tenant{i}" for i in range(n_tenants)]
    adapters = synth_tenant_adapters(jax.random.PRNGKey(0), cfg, tenants)
    reqs = make_requests(cfg, tenants, n_requests, PREFILL_LEN, gen_tokens,
                         seed=0)

    engine = ServingEngine(
        cfg, backbone, max_slots=SLOTS, prefill_len=PREFILL_LEN,
        max_new_tokens=gen_tokens, adapter_slots=max(SLOTS, 8),
        adapter_loader=adapters.__getitem__)
    engine.run(reqs)                       # warmup: compiles, discarded
    engine.stats = {"decode_steps": 0, "prefills": 0, "occupancy_sum": 0}
    t0 = time.time()
    got = engine.run(reqs)
    t_engine = time.time() - t0

    generate_naive(cfg, backbone, reqs, adapters)   # warmup (per-length jits)
    t0 = time.time()
    ref = generate_naive(cfg, backbone, reqs, adapters)
    t_naive = time.time() - t0

    mismatch = [r.rid for r in reqs if got[r.rid].tokens != ref[r.rid].tokens]
    if mismatch:
        raise SystemExit(f"token mismatch engine vs naive: rids {mismatch}")

    n_tok = sum(len(c.tokens) for c in got.values())
    row = {
        "tenants": n_tenants,
        "requests": n_requests,
        "gen_tokens": gen_tokens,
        "total_tokens": n_tok,
        "engine_s": round(t_engine, 4),
        "naive_s": round(t_naive, 4),
        "engine_tok_s": round(n_tok / t_engine, 2),
        "naive_tok_s": round(n_tok / t_naive, 2),
        "speedup": round(t_naive / t_engine, 2),
        "mean_occupancy": round(engine.mean_occupancy(), 2),
    }
    print(f"  tenants={n_tenants:>3}  reqs={n_requests:>4}  "
          f"engine={row['engine_tok_s']:8.1f} tok/s  "
          f"naive={row['naive_tok_s']:8.1f} tok/s  "
          f"speedup={row['speedup']:.2f}x  occ={row['mean_occupancy']}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", default=None,
                    help="comma-separated tenant counts (default 1,8,64)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny workload, no JSON written — wiring check")
    ap.add_argument("--out", default=None,
                    help=f"output JSON (default {OUT}; --quick skips writing)")
    args = ap.parse_args(argv)

    if args.tenants:
        sizes = [int(s) for s in args.tenants.split(",")]
    elif args.quick:
        sizes = [2]
    else:
        sizes = [1, 8, 64]

    cfg = get_smoke_config(ARCH)
    backbone = model_lib.init_backbone(jax.random.PRNGKey(0), cfg)
    gen_tokens = 4 if args.quick else GEN_TOKENS
    print(f"### serve bench: {ARCH}, slots={SLOTS}, "
          f"prefill_len={PREFILL_LEN}, gen_tokens={gen_tokens}, "
          "token parity asserted per row")
    rows = []
    for n in sizes:
        n_requests = 8 if args.quick else max(2 * n, 32)
        rows.append(bench_tenants(cfg, backbone, n, n_requests, gen_tokens))

    out_path = args.out or (None if args.quick else OUT)
    if out_path:
        doc = {"config": {
            "arch": f"{ARCH} (smoke scale)", "slots": SLOTS,
            "prefill_len": PREFILL_LEN, "gen_tokens": gen_tokens,
            "timing": "fresh full-workload run after a warmup run that pays "
                      "all compilation; throughput = generated tokens / wall",
        }, "results": []}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    doc["results"] = json.load(f).get("results", [])
            except (json.JSONDecodeError, OSError):
                pass
        done = {r["tenants"] for r in rows}
        doc["results"] = sorted(
            [r for r in doc["results"] if r["tenants"] not in done] + rows,
            key=lambda r: r["tenants"])
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
