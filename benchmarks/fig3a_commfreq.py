"""Fig. 3a — impact of communication frequency.

Fixed total local compute (rounds × local_steps = const); vary how often
clients synchronize. Paper claim validated: all methods degrade with less
frequent communication, and FedNano's margin over FedAvg grows as
communication becomes more frequent.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_strategy

# (rounds, local_steps): total steps 12 in all cells
GRID = [(8, 5), (4, 10), (1, 40)]


def run(quick: bool = True):
    rows_csv = []
    print("\n### Fig. 3a — communication frequency (total local steps fixed at 40)")
    margins = {}
    for rounds, steps in GRID:
        accs = {}
        for strat in ("fedavg", "fednano"):
            res, dt = run_strategy("minigpt4", strat, rounds=rounds,
                                   local_steps=steps, seed=6)
            accs[strat] = res["avg_accuracy"]
            rows_csv.append(csv_row(f"fig3a/R{rounds}xT{steps}/{strat}", dt,
                                    f"{res['avg_accuracy']:.4f}"))
        margins[rounds] = accs["fednano"] - accs["fedavg"]
        print(f"    R={rounds:<2} T={steps:<3} fedavg {100*accs['fedavg']:.2f}  "
              f"fednano {100*accs['fednano']:.2f}  margin {100*margins[rounds]:+.2f}")
    freq_sorted = sorted(margins)  # ascending rounds == ascending frequency
    print(f"    paper trend: margin at R={freq_sorted[-1]} ≥ margin at R={freq_sorted[0]} -> "
          f"{margins[freq_sorted[-1]] >= margins[freq_sorted[0]]}")
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
