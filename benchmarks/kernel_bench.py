"""Pallas kernels: interpret-mode correctness timing + TPU roofline projections.

No TPU here — wall times below are CPU interpret-mode (correctness path) and
meaningless as TPU perf; the 'derived' column instead reports the v5e
roofline projection (theoretical min time from bytes/flops) per kernel at a
production-relevant shape.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(f, *args, n=3):
    f(*args)  # compile/warm
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    print("\n### Kernel bench (CPU interpret mode; derived = v5e roofline projection)")

    # --- LoRA: T=4096 tokens, D=4096, r=64 ---
    T, D, r = (512, 512, 16) if quick else (4096, 4096, 64)
    from repro.kernels.lora import ops as lora_ops

    x = jax.random.normal(key, (T, D), jnp.float32)
    a = jax.random.normal(key, (D, r)) * 0.02
    b = jax.random.normal(key, (r, D)) * 0.02
    dt = _time(lambda *z: lora_ops.lora_residual(*z, scale=2.0, interpret=True), x, a, b)
    flops = 4 * T * D * r
    bytes_ = (2 * T * D + 2 * D * r) * 2  # bf16 on TPU
    proj = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
    rows.append(("kernels/lora_fused", dt, f"roofline_us={proj*1e6:.1f}"))
    print(f"    lora      T{T} D{D} r{r}: interpret {dt*1e3:.0f}ms; v5e roofline {proj*1e6:.1f}us "
          f"({'memory' if bytes_/HBM_BW > flops/PEAK_FLOPS_BF16 else 'compute'}-bound)")

    # --- Fisher merge: K=10 clients × 1.05M params ---
    K, N = (5, 1 << 16) if quick else (10, 1 << 20)
    from repro.kernels.fisher_merge import ops as fm_ops

    t = jax.random.normal(key, (K, N))
    f = jax.random.uniform(key, (K, N), minval=0.01)
    w = jnp.ones((K,))
    dt = _time(lambda *z: fm_ops.fisher_merge(*z, interpret=True), t, f, w)
    bytes_ = (2 * K * N + N) * 4
    proj = bytes_ / HBM_BW
    rows.append(("kernels/fisher_merge", dt, f"roofline_us={proj*1e6:.1f}"))
    print(f"    fisher    K{K} N{N}: interpret {dt*1e3:.0f}ms; v5e roofline {proj*1e6:.1f}us (memory-bound)")

    # --- Flash attention: B1 S2048 H8 D128 causal ---
    B, S, H, Dh = (1, 256, 4, 64) if quick else (1, 2048, 8, 128)
    from repro.kernels.flash_attention import ops as fa_ops

    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    v = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    dt = _time(lambda *z: fa_ops.flash_attention(*z, block_q=128, block_k=128,
                                                 interpret=True), q, k, v)
    flops = 4 * B * H * S * S * Dh / 2  # causal half
    bytes_ = 4 * B * S * H * Dh * 2
    proj = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
    rows.append(("kernels/flash_attention", dt, f"roofline_us={proj*1e6:.1f}"))
    print(f"    flash     B{B} S{S} H{H} D{Dh}: interpret {dt*1e3:.0f}ms; v5e roofline {proj*1e6:.1f}us "
          f"({'compute' if flops/PEAK_FLOPS_BF16 > bytes_/HBM_BW else 'memory'}-bound)")

    # --- SSD: mamba2-130m layer shape ---
    Bt, S2, Hs, P, Ns, Q = (1, 256, 4, 32, 32, 64) if quick else (1, 2048, 24, 64, 128, 256)
    from repro.kernels.ssd_scan import ops as ssd_ops

    xs = jax.random.normal(key, (Bt, S2, Hs, P)) * 0.5
    dts = jax.random.uniform(key, (Bt, S2, Hs), minval=0.01, maxval=0.2)
    A = -jnp.ones((Hs,))
    Bm = jax.random.normal(key, (Bt, S2, Ns)) * 0.3
    Cm = jax.random.normal(key, (Bt, S2, Ns)) * 0.3
    dt = _time(lambda *z: ssd_ops.ssd(*z, chunk=Q, interpret=True), xs, dts, A, Bm, Cm)
    flops = Bt * Hs * (S2 // Q) * (2 * Q * Q * Ns + 2 * Q * Q * P + 4 * Q * Ns * P)
    bytes_ = (Bt * S2 * Hs * P * 2 + 2 * Bt * S2 * Ns) * 2
    proj = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
    rows.append(("kernels/ssd_scan", dt, f"roofline_us={proj*1e6:.1f}"))
    print(f"    ssd       B{Bt} S{S2} H{Hs}: interpret {dt*1e3:.0f}ms; v5e roofline {proj*1e6:.1f}us")

    return [(n, w, d) for n, w, d in rows]


if __name__ == "__main__":
    run(quick=False)
