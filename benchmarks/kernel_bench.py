"""Pallas kernel perf trajectory: interpret/ref wall times + v5e rooflines.

No TPU here — interpret-mode wall time is CPU executing the kernel body in
Python and is meaningless as TPU perf. What IS meaningful, and what this
bench pins across PRs:

  * ``ref_ms`` — the jitted XLA reference on this CPU (a real baseline);
  * ``interpret_ms`` — tracks kernel-body complexity; a PR that regresses
    it 10x changed the kernel's work, not the machine;
  * ``roofline_us`` — the v5e analytic floor (bytes/BW vs flops/peak) at
    the benched shape, the number the perf rungs are closing in on.

Full runs APPEND one row per kernel family to BENCH_kernels.json at the
repo root. The trajectory is append-only: rows from earlier runs are never
edited or dropped, every run gets the next ``seq`` number, so the file is
a perf history readable by diffing adjacent seqs (tests/test_bench_schema.py
enforces the invariants).

Usage:
    PYTHONPATH=src python benchmarks/kernel_bench.py            # append a run
    PYTHONPATH=src python benchmarks/kernel_bench.py --quick    # ~20 s parity
                                                                #  gate, no JSON
    PYTHONPATH=src python benchmarks/kernel_bench.py --sweep    # block-size
                                                                #  sweep feeding
                                                                #  kernels/tuning.py
    PYTHONPATH=src python benchmarks/kernel_bench.py --label "pr9 streaming"
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
sys.path.insert(0, os.path.join(_HERE, "..", "tests"))

import jax
import jax.numpy as jnp

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

ROOT = os.path.join(_HERE, "..")
OUT = os.path.join(ROOT, "BENCH_kernels.json")

FAMILIES = ("lora", "grouped_lora", "fisher_merge", "fisher_merge_stream",
            "flash_attention", "ssd_scan")


def _time(f, *args, n: int = 3) -> float:
    jax.block_until_ready(f(*args))  # compile/warm
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n


def _row(kernel, shape, interpret_s, ref_s, flops, bytes_, blocks=None):
    t_c = flops / PEAK_FLOPS_BF16
    t_m = bytes_ / HBM_BW
    row = {
        "kernel": kernel,
        "shape": shape,
        "dtype": "float32",
        "interpret_ms": round(interpret_s * 1e3, 3),
        "ref_ms": round(ref_s * 1e3, 3),
        "roofline_us": round(max(t_c, t_m) * 1e6, 3),
        "bound": "compute" if t_c > t_m else "memory",
    }
    if blocks:
        row["blocks"] = blocks
    print(f"    {kernel:<20} {str(shape):<44} interpret {row['interpret_ms']:9.1f}ms"
          f"  ref {row['ref_ms']:7.2f}ms  v5e roofline {row['roofline_us']:8.1f}us"
          f" ({row['bound']}-bound)")
    return row


# --------------------------------------------------------------------------
# per-family benches — every input gets its own PRNG key via jax.random.split
# --------------------------------------------------------------------------

def bench_lora(key, quick):
    from repro.kernels import tuning
    from repro.kernels.lora import ops as lora_ops, ref as lora_ref

    T, D, r = (512, 512, 16) if quick else (2048, 2048, 64)
    kx, ka, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (T, D), jnp.float32)
    a = jax.random.normal(ka, (D, r)) * 0.02
    b = jax.random.normal(kb, (r, D)) * 0.02
    bt = tuning.lora_block_t(T, D, r)
    dt = _time(lambda *z: lora_ops.lora_residual(*z, scale=2.0, interpret=True), x, a, b)
    dr = _time(jax.jit(lambda *z: lora_ref.lora_residual(*z, scale=2.0)), x, a, b)
    flops = 4 * T * D * r
    bytes_ = (2 * T * D + 2 * D * r) * 2  # bf16 on TPU
    return _row("lora", {"T": T, "D": D, "r": r}, dt, dr, flops, bytes_,
                blocks={"block_t": bt})


def bench_grouped_lora(key, quick):
    from repro.kernels import tuning
    from repro.kernels.lora import ops as lora_ops, ref as lora_ref

    T, D, r, n = (512, 512, 16, 4) if quick else (2048, 2048, 64, 8)
    kx, ka, kb, ki = jax.random.split(key, 4)
    x = jax.random.normal(kx, (T, D), jnp.float32)
    a = jax.random.normal(ka, (n, D, r)) * 0.02
    b = jax.random.normal(kb, (n, r, D)) * 0.02
    idx = jax.random.randint(ki, (T,), -1, n)
    bt = tuning.lora_block_t(T, D, r)
    dt = _time(lambda *z: lora_ops.grouped_lora_residual(*z, scale=2.0, interpret=True),
               x, a, b, idx)
    dr = _time(jax.jit(lambda *z: lora_ref.grouped_lora_residual(*z, scale=2.0)),
               x, a, b, idx)
    flops = 4 * T * D * r
    bytes_ = (2 * T * D + 2 * n * D * r) * 2 + 4 * T  # all adapters + idx stream
    return _row("grouped_lora", {"T": T, "D": D, "r": r, "n_adapters": n}, dt, dr,
                flops, bytes_, blocks={"block_t": bt})


def bench_fisher(key, quick):
    from repro.kernels import tuning
    from repro.kernels.fisher_merge import ops as fm_ops, ref as fm_ref

    K, N = (5, 1 << 16) if quick else (10, 1 << 20)
    kt, kf = jax.random.split(key)
    t = jax.random.normal(kt, (K, N))
    f = jax.random.uniform(kf, (K, N), minval=0.01)
    w = jnp.ones((K,))
    bn = tuning.fisher_block_n(K, N)
    dt = _time(lambda *z: fm_ops.fisher_merge(*z, interpret=True), t, f, w)
    dr = _time(jax.jit(fm_ref.fisher_merge), t, f, w)
    bytes_ = (2 * K * N + N) * 4
    return _row("fisher_merge", {"K": K, "N": N}, dt, dr, 4 * K * N, bytes_,
                blocks={"block_n": bn})


def bench_fisher_stream(key, quick):
    from repro.kernels import tuning
    from repro.kernels.fisher_merge import ops as fm_ops, ref as fm_ref

    K, N = (5, 1 << 16) if quick else (10, 1 << 20)
    kt, kf = jax.random.split(key)
    t = jax.random.normal(kt, (K, N))
    f = jax.random.uniform(kf, (K, N), minval=0.01)
    bn = tuning.fisher_block_n(1, N)

    def stream(t, f):
        num = jnp.zeros((N,), jnp.float32)
        den = jnp.zeros((N,), jnp.float32)
        for i in range(K):
            num, den = fm_ops.fisher_fold(num, den, t[i], f[i], 1.0, interpret=True)
        return fm_ref.fisher_finalize(num, den)

    def stream_ref(t, f):
        num = jnp.zeros((N,), jnp.float32)
        den = jnp.zeros((N,), jnp.float32)
        for i in range(K):
            num, den = fm_ref.fisher_fold(num, den, t[i], f[i], 1.0)
        return fm_ref.fisher_finalize(num, den)

    dt = _time(stream, t, f)
    dr = _time(jax.jit(stream_ref), t, f)
    # per fold: read num/den/theta/fisher, write num/den — all f32
    bytes_ = K * 6 * N * 4
    return _row("fisher_merge_stream", {"K": K, "N": N}, dt, dr, 4 * K * N, bytes_,
                blocks={"block_n": bn})


def bench_flash(key, quick):
    from repro.kernels import tuning
    from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref

    B, S, H, Dh = (1, 256, 4, 64) if quick else (1, 1024, 8, 128)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, Dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, Dh), jnp.float32)
    bq, bk = tuning.flash_blocks(S, S, Dh)
    dt = _time(lambda *z: fa_ops.flash_attention(*z, interpret=True), q, k, v)
    dr = _time(jax.jit(fa_ref.attention), q, k, v)
    flops = 4 * B * H * S * S * Dh / 2  # causal half
    bytes_ = 4 * B * S * H * Dh * 2
    return _row("flash_attention", {"B": B, "S": S, "H": H, "D": Dh}, dt, dr,
                flops, bytes_, blocks={"block_q": bq, "block_k": bk})


def bench_ssd(key, quick):
    from repro.kernels import tuning
    from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

    Bt, S, Hs, P, Ns = (1, 256, 4, 32, 32) if quick else (1, 1024, 8, 64, 64)
    kx, kd, kb, kc = jax.random.split(key, 4)
    x = jax.random.normal(kx, (Bt, S, Hs, P)) * 0.5
    dts = jax.random.uniform(kd, (Bt, S, Hs), minval=0.01, maxval=0.2)
    A = -jnp.ones((Hs,))
    Bm = jax.random.normal(kb, (Bt, S, Ns)) * 0.3
    Cm = jax.random.normal(kc, (Bt, S, Ns)) * 0.3
    Q = tuning.ssd_chunk(S, P, Ns)
    dt = _time(lambda *z: ssd_ops.ssd(*z, chunk=Q, interpret=True), x, dts, A, Bm, Cm)
    dr = _time(jax.jit(lambda *z: ssd_ref.ssd_chunked(*z, Q)), x, dts, A, Bm, Cm)
    flops = Bt * Hs * (S // Q) * (2 * Q * Q * Ns + 2 * Q * Q * P + 4 * Q * Ns * P)
    bytes_ = (Bt * S * Hs * P * 2 + 2 * Bt * S * Ns) * 2
    return _row("ssd_scan", {"B": Bt, "S": S, "H": Hs, "P": P, "N": Ns}, dt, dr,
                flops, bytes_, blocks={"chunk": Q})


BENCHES = {
    "lora": bench_lora,
    "grouped_lora": bench_grouped_lora,
    "fisher_merge": bench_fisher,
    "fisher_merge_stream": bench_fisher_stream,
    "flash_attention": bench_flash,
    "ssd_scan": bench_ssd,
}


# --------------------------------------------------------------------------
# block-size sweep — the measurement behind kernels/tuning.PINNED
# --------------------------------------------------------------------------

def sweep(key):
    """Time each family at candidate block sizes (quick shapes: interpret
    mode scales with the grid structure, which is what blocks change)."""
    from repro.kernels.fisher_merge import ops as fm_ops
    from repro.kernels.flash_attention import ops as fa_ops
    from repro.kernels.lora import ops as lora_ops

    out = {}
    kx, ka, kb = jax.random.split(jax.random.fold_in(key, 1), 3)
    T, D, r = 512, 512, 16
    x = jax.random.normal(kx, (T, D))
    a = jax.random.normal(ka, (D, r)) * 0.02
    b = jax.random.normal(kb, (r, D)) * 0.02
    out["lora/block_t"] = {
        str(bt): round(_time(lambda *z: lora_ops.lora_residual(
            *z, scale=2.0, block_t=bt, interpret=True), x, a, b) * 1e3, 2)
        for bt in (64, 128, 256, 512)}

    kt, kf = jax.random.split(jax.random.fold_in(key, 2))
    K, N = 5, 1 << 16
    t = jax.random.normal(kt, (K, N))
    f = jax.random.uniform(kf, (K, N), minval=0.01)
    w = jnp.ones((K,))
    out["fisher_merge/block_n"] = {
        str(bn): round(_time(lambda *z: fm_ops.fisher_merge(
            *z, block_n=bn, interpret=True), t, f, w) * 1e3, 2)
        for bn in (256, 512, 1024, 2048)}

    kq, kk, kv = jax.random.split(jax.random.fold_in(key, 3), 3)
    B, S, H, Dh = 1, 256, 4, 64
    q = jax.random.normal(kq, (B, S, H, Dh))
    kk_ = jax.random.normal(kk, (B, S, H, Dh))
    vv = jax.random.normal(kv, (B, S, H, Dh))
    out["flash_attention/block_q_k"] = {
        f"{bq}x{bk}": round(_time(lambda *z: fa_ops.flash_attention(
            *z, block_q=bq, block_k=bk, interpret=True), q, kk_, vv) * 1e3, 2)
        for bq, bk in ((64, 64), (128, 128), (128, 256), (256, 128))}

    for name, table in out.items():
        best = min(table, key=table.get)
        print(f"    sweep {name:<28} " +
              "  ".join(f"{k}:{v}ms" for k, v in table.items()) +
              f"   -> best {best}")
    return out


# --------------------------------------------------------------------------
# parity gate (--quick): one harness smoke case per family, no JSON
# --------------------------------------------------------------------------

def parity_gate():
    import kernel_harness as kh

    key = jax.random.PRNGKey(7)
    for case in kh.smoke_cases():
        kh.check_case(case, jax.random.fold_in(key, hash(case.id) % (1 << 30)))
        print(f"    parity OK  {case.id}")


def run(quick: bool = True, key=None):
    """Programmatic entry (benchmarks/run.py): returns (name, wall, note) rows."""
    key = jax.random.PRNGKey(0) if key is None else key
    print("\n### Kernel bench (CPU interpret mode; roofline = v5e projection)")
    rows = []
    for i, fam in enumerate(FAMILIES):
        rows.append(BENCHES[fam](jax.random.fold_in(key, i), quick))
    return [(f"kernels/{r['kernel']}", r["interpret_ms"] / 1e3,
             f"roofline_us={r['roofline_us']}") for r in rows]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="parity gate + small shapes, no JSON written")
    ap.add_argument("--sweep", action="store_true",
                    help="block-size sweep (informs kernels/tuning.PINNED)")
    ap.add_argument("--label", default="run",
                    help="label stamped on this run's rows in the trajectory")
    ap.add_argument("--out", default=None,
                    help=f"output JSON (default {OUT}; --quick skips writing)")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    if args.quick:
        print("### kernel parity gate (harness smoke cases)")
        parity_gate()

    print("\n### Kernel bench (CPU interpret mode; roofline = v5e projection)")
    rows = []
    for i, fam in enumerate(FAMILIES):
        row = BENCHES[fam](jax.random.fold_in(key, i), args.quick)
        rows.append(row)

    sweep_tables = None
    if args.sweep:
        print("\n### block-size sweep")
        sweep_tables = sweep(jax.random.fold_in(key, 1000))

    out_path = args.out or (None if args.quick else OUT)
    if out_path:
        doc = {"config": {
            "device": "cpu (Pallas interpret mode); roofline projected for TPU v5e",
            "roofline": {"peak_flops_bf16": PEAK_FLOPS_BF16, "hbm_bw": HBM_BW},
            "schema": "append-only: each run appends one row per kernel family with "
                      "the next seq; existing rows are never edited or removed",
        }, "results": []}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    doc = json.load(f)
            except (json.JSONDecodeError, OSError):
                pass
        prev = doc.get("results", [])
        seq = 1 + max((r.get("seq", 0) for r in prev), default=0)
        for row in rows:
            row["seq"] = seq
            row["label"] = args.label
            if sweep_tables is not None:
                row["sweep"] = {k: v for k, v in sweep_tables.items()
                                if k.startswith(row["kernel"] + "/")} or None
                if row["sweep"] is None:
                    del row["sweep"]
        doc["results"] = prev + rows
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"appended seq={seq} ({len(rows)} rows) to {out_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
