"""Tab. 4 — scalability to 10 clients (MiniGPT-4-like backbone, IconQA-like).

Paper claim validated: FedNano keeps the best average accuracy as the
federation fragments from 5 to 10 clients.
"""
from __future__ import annotations

from benchmarks.common import csv_row, print_table, run_strategy

STRATS = ["locft", "fedavg", "fedprox", "fednano"]


def run(quick: bool = True):
    rows_csv, rows = [], []
    for strat in STRATS:
        res, dt = run_strategy("minigpt4", strat, clients=10, rounds=4,
                               examples_per_client=24, seed=2)
        rows.append((strat, res))
        rows_csv.append(csv_row(f"table4/10clients/{strat}", dt, f"{res['avg_accuracy']:.4f}"))
    print_table("Table 4 — 10 simulated clients", rows)
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
