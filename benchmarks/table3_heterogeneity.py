"""Tab. 3 — robustness under data heterogeneity: α ∈ {0.1, 5}.

Paper claim validated: FedNano's advantage over FedAvg is largest in the
strongly non-IID regime (α=0.1) and narrows when data is near-IID (α=5).
"""
from __future__ import annotations

from benchmarks.common import csv_row, print_table, run_strategy

STRATS = ["locft", "fedavg", "fedprox", "fednano"]


def run(quick: bool = True):
    rows_csv = []
    gaps = {}
    for alpha in (0.1, 5.0):
        rows = []
        for strat in STRATS:
            res, dt = run_strategy("minigpt4", strat, alpha=alpha, rounds=4, seed=1)
            rows.append((strat, res))
            rows_csv.append(csv_row(f"table3/alpha{alpha}/{strat}", dt,
                                    f"{res['avg_accuracy']:.4f}"))
        print_table(f"Table 3 — MiniGPT-4-like backbone, α={alpha}", rows)
        accs = dict((n, r["avg_accuracy"]) for n, r in rows)
        gaps[alpha] = accs["fednano"] - accs["fedavg"]
        print(f"    FedNano − FedAvg gap @α={alpha}: {100*gaps[alpha]:+.2f}")
    print(f"\n    paper trend (gap larger at small α): "
          f"gap(0.1)={100*gaps[0.1]:+.2f} vs gap(5)={100*gaps[5.0]:+.2f}")
    rows_csv.append(csv_row("table3/gap_shrinks_with_alpha", 0.0,
                            f"{gaps[0.1] >= gaps[5.0]}"))
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
