"""Beyond-paper extensions benchmark (not a paper table).

Quantifies the three extensions against the paper's own axes:
  * int8 delta compression + error feedback — upload bytes vs accuracy
  * client-level DP (clip + Gaussian noise)  — privacy noise vs accuracy
  * rank-heterogeneous clients               — merged-rank correctness
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, csv_row
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data


def run(quick: bool = True):
    rows = []
    cfg = bench_config("minigpt4-7b")
    train, evald, _ = make_federated_data(
        cfg, n_clients=3, examples_per_client=32, alpha=1.0, batch_size=8, seq_len=24
    )
    key = jax.random.PRNGKey(9)
    print("\n### Beyond-paper extensions (FedNano, 3 clients, 3 rounds)")

    base_hp = HyperParams(lr=1e-2, local_steps=8, fisher_batches=2)
    res0 = run_federated(key, cfg, train, evald, strategy="fednano", rounds=3, hp=base_hp)
    print(f"    baseline          acc {100*res0.avg_accuracy:.2f}  "
          f"upload {res0.comm_totals['param_up']/1024:.0f} KiB")
    rows.append(csv_row("ext/baseline", 0.0, f"{res0.avg_accuracy:.4f}"))

    hp_c = HyperParams(lr=1e-2, local_steps=8, fisher_batches=2, compress_uploads=True)
    res1 = run_federated(key, cfg, train, evald, strategy="fednano", rounds=3, hp=hp_c)
    ratio = res1.comm_totals["param_up"] / max(res1.comm_totals["param_up_wire"], 1)
    print(f"    + int8 compress   acc {100*res1.avg_accuracy:.2f}  "
          f"wire {res1.comm_totals['param_up_wire']/1024:.0f} KiB  ({ratio:.2f}x smaller)")
    rows.append(csv_row("ext/int8_compress", 0.0,
                        f"acc={res1.avg_accuracy:.4f};ratio={ratio:.2f}x"))

    hp_dp = HyperParams(lr=1e-2, local_steps=8, fisher_batches=2,
                        dp_clip=1.0, dp_noise=0.01)
    res2 = run_federated(key, cfg, train, evald, strategy="fednano", rounds=3, hp=hp_dp)
    print(f"    + DP (C=1, σ=.01) acc {100*res2.avg_accuracy:.2f}  "
          f"(noise dim = adapters only: {res2.comm_totals['param_up']//4//3//3} params/client)")
    rows.append(csv_row("ext/dp", 0.0, f"{res2.avg_accuracy:.4f}"))

    # heterogeneous ranks: merge rank {2, 4, 8} clients, serve each its slice
    from repro.core.hetero import hetero_fisher_merge, truncate_nanoedge
    from repro.core import adapters as A

    ranks = [2, 4, 8]
    thetas = []
    for i, r in enumerate(ranks):
        c = cfg.with_(adapter=cfg.adapter.__class__(
            rank=r, alpha=2.0 * r, modalities=cfg.adapter.modalities))
        thetas.append(A.init_nanoedge(jax.random.fold_in(key, i), c))
    merged = hetero_fisher_merge(thetas, [None] * 3, ranks)
    served = truncate_nanoedge(merged, 2)
    ok = merged["text"]["down"].shape == (cfg.d_model, 8) and served["text"]["down"].shape == (cfg.d_model, 2)
    print(f"    hetero ranks {ranks}: merged rank-8, served rank-2 slice -> {'ok' if ok else 'FAIL'}")
    rows.append(csv_row("ext/hetero_ranks", 0.0, str(ok)))
    return rows


if __name__ == "__main__":
    run(quick=False)
