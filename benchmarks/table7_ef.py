"""Tab. 7 — FedNano vs FedNano-EF (Fisher-estimation trade-off).

Paper claim validated: FedNano ≥ FedNano-EF ≥ FedAvg, with FedNano-EF
eliminating the dedicated FIM pass (compute parity with FedAvg) at a small
accuracy cost.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_strategy

STRATS = ["fednano", "fednano_ef", "fedavg"]


def run(quick: bool = True):
    rows_csv = []
    print("\n### Table 7 — precise vs streaming Fisher (minigpt4-like backbone)")
    accs, walls = {}, {}
    for strat in STRATS:
        res, dt = run_strategy("minigpt4", strat, rounds=4, seed=5)
        accs[strat], walls[strat] = res["avg_accuracy"], dt
        rows_csv.append(csv_row(f"table7/{strat}", dt, f"{res['avg_accuracy']:.4f}"))
        print(f"    {strat:<12} acc {100*res['avg_accuracy']:.2f}  wall {dt:.1f}s")
    print(f"    EF removes the extra FIM pass: wall {walls['fednano_ef']:.1f}s vs "
          f"{walls['fednano']:.1f}s (FedAvg {walls['fedavg']:.1f}s)")
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
