"""§Roofline table: aggregates the dry-run JSON records into the per-pair
roofline summary (compute/memory/collective seconds, bottleneck, useful %).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun).
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(mode: str = "roofline", tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mode") != mode:
            continue
        if (r.get("tag") or "") != tag:
            continue
        recs.append(r)
    return recs


def fmt_table(recs):
    lines = []
    head = (f"{'arch':<24}{'shape':<13}{'comp_ms':>9}{'mem_ms':>9}{'coll_ms':>9}"
            f"{'bottleneck':>12}{'useful%':>9}")
    lines.append(head)
    lines.append("-" * len(head))
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9)
    for r in sorted(recs, key=key):
        if r["status"] == "skip":
            lines.append(f"{r['arch']:<24}{r['shape']:<13}{'— skipped: ' + r['reason']}")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:<24}{r['shape']:<13}ERROR {r.get('error','')[:60]}")
            continue
        lines.append(
            f"{r['arch']:<24}{r['shape']:<13}{1e3*r['t_compute']:>9.2f}"
            f"{1e3*r['t_memory']:>9.2f}{1e3*r['t_collective']:>9.2f}"
            f"{r['bottleneck']:>12}{100*r['useful_ratio']:>8.0f}%"
        )
    return "\n".join(lines)


def run(quick: bool = True):
    recs = load_records("roofline")
    rows = []
    if not recs:
        print("\n### §Roofline table: no dry-run records yet "
              "(run python -m repro.launch.dryrun --all --mode roofline --out benchmarks/results/dryrun)")
        return rows
    print("\n### §Roofline — BASELINE (paper-faithful sharding), single-pod 16×16, v5e terms")
    print(fmt_table(recs))
    ok = [r for r in recs if r["status"] == "ok"]
    for r in ok:
        rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                     f"{r['bottleneck']}:{1e3*max(r['t_compute'], r['t_memory'], r['t_collective']):.1f}ms"))

    # beyond-paper optimized variants (tag=opt), with speedup on the dominant term
    opt = {(r["arch"], r["shape"]): r for r in load_records("roofline", tag="opt")
           if r["status"] == "ok"}
    base = {(r["arch"], r["shape"]): r for r in ok}
    if opt:
        print("\n### §Roofline — OPTIMIZED (beyond-paper sharding/dataflow, §Perf) vs baseline dominant term")
        head = (f"{'arch':<24}{'shape':<13}{'comp_ms':>9}{'mem_ms':>9}{'coll_ms':>9}"
                f"{'bottleneck':>12}{'dom_speedup':>12}")
        print(head)
        print("-" * len(head))
        for key in sorted(opt):
            r = opt[key]
            b = base.get(key)
            dom_b = max(b["t_compute"], b["t_memory"], b["t_collective"]) if b else 0
            dom_o = max(r["t_compute"], r["t_memory"], r["t_collective"])
            sp = dom_b / dom_o if dom_o else 0
            print(f"{r['arch']:<24}{r['shape']:<13}{1e3*r['t_compute']:>9.2f}"
                  f"{1e3*r['t_memory']:>9.2f}{1e3*r['t_collective']:>9.2f}"
                  f"{r['bottleneck']:>12}{sp:>11.2f}x")
            rows.append((f"roofline_opt/{r['arch']}/{r['shape']}", 0.0, f"speedup:{sp:.2f}x"))

    # fits summary from full-mode records
    full = load_records("full")
    n_ok = sum(1 for r in full if r["status"] == "ok")
    n_skip = sum(1 for r in full if r["status"] == "skip")
    n_err = len(full) - n_ok - n_skip
    print(f"\n    full-config dry-runs: {n_ok} ok / {n_skip} documented skips / {n_err} errors")
    rows.append(("dryrun/full_ok", 0.0, str(n_ok)))
    rows.append(("dryrun/full_errors", 0.0, str(n_err)))
    return rows


if __name__ == "__main__":
    run()
