"""Tab. 6 — necessity of combining 𝒜_T and 𝒜_I.

Paper claim validated: on the vision-centric synthetic VQA task, 𝒜_T alone
is weakest (the disambiguating `detail` signal lives in the image stream),
𝒜_I alone is strong, and 𝒜_T + 𝒜_I is best.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_strategy

VARIANTS = [("A_T", ("text",)), ("A_I", ("image",)), ("A_T+A_I", ("text", "image"))]


def run(quick: bool = True):
    rows_csv = []
    accs = {}
    print("\n### Table 6 — adapter ablation (FedNano, minigpt4-like backbone)")
    for name, mods in VARIANTS:
        res, dt = run_strategy("minigpt4", "fednano", modalities=mods, rounds=4, seed=4)
        accs[name] = res["avg_accuracy"]
        rows_csv.append(csv_row(f"table6/{name}", dt, f"{res['avg_accuracy']:.4f}"))
        print(f"    {name:<8} {100*res['avg_accuracy']:.2f}")
    print(f"    paper trend (A_T weakest, combo best): "
          f"A_T={100*accs['A_T']:.2f} ≤ A_I={100*accs['A_I']:.2f} ≤ "
          f"A_T+A_I={100*accs['A_T+A_I']:.2f}")
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
