"""Fig. 3b — effect of NanoAdapter rank.

Paper claim validated: accuracy grows with rank for both methods, FedNano
stays ahead of FedAvg across ranks, and uploads scale linearly with rank
(the performance/communication trade-off).
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_strategy

RANKS = [2, 8, 32]


def run(quick: bool = True):
    rows_csv = []
    print("\n### Fig. 3b — adapter rank sweep (ScienceQA-like)")
    for rank in RANKS:
        accs, up = {}, None
        for strat in ("fedavg", "fednano"):
            res, dt = run_strategy("minigpt4", strat, rank=rank, rounds=4, seed=7)
            accs[strat] = res["avg_accuracy"]
            up = res["comm_totals"]["param_up"]
            rows_csv.append(csv_row(f"fig3b/rank{rank}/{strat}", dt,
                                    f"{res['avg_accuracy']:.4f}"))
        print(f"    rank {rank:<3} fedavg {100*accs['fedavg']:.2f}  "
              f"fednano {100*accs['fednano']:.2f}  upload/round/client "
              f"{up/3/5/1024:.0f} KiB")
    return rows_csv


if __name__ == "__main__":
    run(quick=False)
