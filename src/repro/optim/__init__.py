from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.sgd import SGDState, sgd_init, sgd_update
from repro.optim.schedules import constant_schedule, cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "SGDState",
    "sgd_init",
    "sgd_update",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
    "make_optimizer",
]


def make_optimizer(name: str, **kw):
    """Small factory: returns (init_fn, update_fn) closures."""
    if name == "adamw":
        return (
            lambda params: adamw_init(params),
            lambda grads, state, params, lr: adamw_update(grads, state, params, lr=lr, **kw),
        )
    if name == "sgd":
        return (
            lambda params: sgd_init(params),
            lambda grads, state, params, lr: sgd_update(grads, state, params, lr=lr, **kw),
        )
    raise ValueError(f"unknown optimizer {name!r}")
