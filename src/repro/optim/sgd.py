"""SGD with optional momentum."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_zeros_like


class SGDState(NamedTuple):
    velocity: dict


def sgd_init(params) -> SGDState:
    return SGDState(velocity=tree_zeros_like(params))


def sgd_update(grads, state: SGDState, params, *, lr: float, momentum: float = 0.0):
    if momentum:
        vel = jax.tree.map(lambda v, g: momentum * v + g, state.velocity, grads)
    else:
        vel = grads
    new_params = jax.tree.map(lambda p, v: (p - lr * v).astype(p.dtype), params, vel)
    return new_params, SGDState(velocity=vel if momentum else state.velocity)
