"""AdamW (decoupled weight decay), pure-pytree implementation."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_zeros_like


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def adamw_init(params) -> AdamWState:
    return AdamWState(
        mu=tree_zeros_like(params),
        nu=tree_zeros_like(params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
):
    step = state.step + 1
    if grad_clip and grad_clip > 0.0:
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p
        return (p - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, step=step)
