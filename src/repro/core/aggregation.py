"""Server-side aggregation strategies (paper §3.4, Eq. 1).

``fisher_merge`` is the paper's contribution: Laplace-posterior merging with
diagonal FIM precision, weighted by client data share p_k = |D_k| / Σ|D_j|:

    θ_global = ( Σ_k p_k F_k θ_k ) / ( Σ_k p_k F_k )        (elementwise)

``fedavg`` is the isotropic special case (F_k ≡ 1). FedProx uses fedavg
aggregation (its difference is the client-side proximal term). FedDPA-F
fedavg-aggregates only the *global* adapter of its dual pair.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.utils import tree_stack


def _norm_weights(sizes: Sequence[float], n: int):
    if sizes is None:
        w = jnp.ones((n,), jnp.float32) / n
    else:
        w = jnp.asarray(sizes, jnp.float32)
        # guard an all-zero-weight cohort (e.g. every row masked out):
        # 0/0 would poison the merge with NaN; fall back to uniform
        total = jnp.sum(w)
        w = jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0),
                      jnp.ones_like(w) / n)
    return w


def fedavg(thetas: List, data_sizes: Optional[Sequence[float]] = None):
    """Data-size-weighted parameter average (McMahan et al. 2017)."""
    w = _norm_weights(data_sizes, len(thetas))
    stacked = tree_stack(thetas)
    return jax.tree.map(
        lambda s: jnp.tensordot(w.astype(s.dtype), s, axes=1), stacked
    )


def fisher_merge(
    thetas: List,
    fishers: List,
    data_sizes: Optional[Sequence[float]] = None,
    *,
    eps: float = 1e-8,
    use_pallas: bool = False,
):
    """Eq. 1: elementwise Fisher-weighted merge over K clients."""
    k = len(thetas)
    assert len(fishers) == k
    w = _norm_weights(data_sizes, k)
    ts = tree_stack(thetas)   # leaves (K, ...)
    fs = tree_stack(fishers)

    if use_pallas:
        from repro.kernels.fisher_merge import ops as fm_ops

        return jax.tree.map(
            lambda t, f: fm_ops.fisher_merge(t, f, w, eps=eps, interpret=True), ts, fs
        )

    def merge(t, f):
        tf = t.astype(jnp.float32)
        ff = f.astype(jnp.float32)
        ww = w.reshape((k,) + (1,) * (t.ndim - 1))
        num = jnp.sum(ww * ff * tf, axis=0)
        den = jnp.sum(ww * ff, axis=0)
        return (num / (den + eps)).astype(t.dtype)

    return jax.tree.map(merge, ts, fs)


STRATEGIES = ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft")


def aggregate(strategy: str, thetas, fishers, data_sizes, *, use_pallas: bool = False):
    if strategy in ("fednano", "fednano_ef"):
        return fisher_merge(thetas, fishers, data_sizes, use_pallas=use_pallas)
    if strategy in ("fedavg", "fedprox", "feddpa_f"):
        return fedavg(thetas, data_sizes)
    if strategy == "locft":
        return None  # no aggregation: clients stay local
    raise ValueError(f"unknown strategy {strategy!r}")
