"""NanoEdge & NanoAdapters — the paper's client-side module (§3.3).

A *NanoAdapter* is a low-rank residual map at the connector→LLM interface:

    y = x + (alpha / rank) · (x · W_down) · W_up

with ``W_up`` zero-initialized (LoRA convention: the adapter is an exact
identity at round 0, preserving the pretrained multimodal alignment). One
adapter per modality: 𝒜_T on text token embeddings, 𝒜_I on connected
image/frame embeddings. They attach **outside** the backbone — the client
never executes or introspects the LLM (DESIGN.md §1).

*NanoEdge* = frozen modality encoder (stub) + frozen connector + frozen token
embedder + trainable NanoAdapters. Only the adapters are trainable/uploaded.

``nanoedge_forward`` assembles backbone-ready embeddings from a Batch — this
is the client half of the split execution; the returned arrays are exactly
the activations that cross the client→server wire in a real deployment.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Batch
from repro.models import model as model_lib
from repro.models.layers import dense_init
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# NanoAdapter
# ---------------------------------------------------------------------------

def init_nano_adapter(key, d_model: int, rank: int, dtype=jnp.float32):
    """LoRA-style pair; up-projection zero-init => identity at init."""
    return {
        "down": dense_init(key, (d_model, rank), dtype),
        "up": jnp.zeros((rank, d_model), dtype),
    }


def nano_adapter_apply(params, x, *, rank: int, alpha: float, use_pallas: bool = False):
    """y = x + (alpha/rank) · (x·down)·up."""
    scale = alpha / rank
    if use_pallas:
        from repro.kernels.lora import ops as lora_ops

        return lora_ops.lora_residual(
            x, params["down"], params["up"], scale=scale, interpret=True
        )
    # compute in the activation dtype (bf16 on the mesh): fp32 master weights
    # are cast at use so no fp32 activation ever crosses a collective
    # (EXPERIMENTS.md §Perf glm4/train iteration 3); grads still flow to the
    # fp32 masters through the cast.
    h = x @ params["down"].astype(x.dtype)
    h = constrain(h, ("data", None, None))
    return x + (h @ params["up"].astype(x.dtype)) * scale


# ---------------------------------------------------------------------------
# NanoEdge (trainable part: the adapter dict)
# ---------------------------------------------------------------------------

def init_nanoedge(key, cfg) -> Dict:
    """Trainable NanoAdapter params, one entry per configured modality."""
    acfg = cfg.adapter
    dtype = jnp.dtype(acfg.dtype)
    keys = jax.random.split(key, len(acfg.modalities))
    return {
        mod: init_nano_adapter(k, cfg.d_model, acfg.rank, dtype)
        for mod, k in zip(acfg.modalities, keys)
    }


def adapter_param_count(cfg) -> int:
    return len(cfg.adapter.modalities) * 2 * cfg.d_model * cfg.adapter.rank


def nanoedge_forward(
    cfg, backbone, adapters, batch: Batch
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Optional[jax.Array]]:
    """Client-side compute: embed + connect + adapt.

    Returns (embeds, positions, labels, mask, enc_embeds):
      embeds     (B, S_total, D) — what the client ships to the server
      positions  (B, S_total) int32
      labels/mask aligned with embeds (image prefix unsupervised)
      enc_embeds (B, M, D) or None — audio-family encoder stream
    """
    acfg = cfg.adapter
    kw = dict(rank=acfg.rank, alpha=acfg.alpha, use_pallas=cfg.use_pallas)

    tok_emb = model_lib.embed_tokens(cfg, backbone, batch.tokens)
    if "text" in adapters:
        tok_emb = nano_adapter_apply(adapters["text"], tok_emb, **kw)

    B, S = batch.tokens.shape

    if cfg.family == "audio":
        enc = model_lib.connect(cfg, backbone, batch.patches)
        if "image" in adapters:
            enc = nano_adapter_apply(adapters["image"], enc, **kw)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return tok_emb, positions, batch.labels, batch.mask, enc

    if cfg.frontend_dim and batch.patches is not None:
        img = model_lib.connect(cfg, backbone, batch.patches)
        if "image" in adapters:
            img = nano_adapter_apply(adapters["image"], img, **kw)
        M = img.shape[1]
        embeds = jnp.concatenate([img.astype(tok_emb.dtype), tok_emb], axis=1)
        positions = jnp.broadcast_to(jnp.arange(M + S, dtype=jnp.int32), (B, M + S))
        pad_lab = jnp.zeros((B, M), batch.labels.dtype)
        pad_mask = jnp.zeros((B, M), batch.mask.dtype)
        labels = jnp.concatenate([pad_lab, batch.labels], axis=1)
        mask = jnp.concatenate([pad_mask, batch.mask], axis=1)
        return embeds, positions, labels, mask, None

    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return tok_emb, positions, batch.labels, batch.mask, None


def fednano_loss(cfg, backbone, adapters, batch: Batch):
    """End-to-end FedNano loss: client NanoEdge -> frozen server backbone.

    Differentiate w.r.t. ``adapters`` only — the backbone is frozen by
    construction (it is a closed-over constant for the gradient).
    """
    embeds, positions, labels, mask, enc = nanoedge_forward(cfg, backbone, adapters, batch)
    loss, aux = model_lib.loss_fn(cfg, backbone, embeds, positions, labels, mask, enc)
    return loss, aux
