"""Split-learning runtime — the mechanics FedNano's Alg. 1 leaves implicit.

The client cannot backprop through a server-hosted LLM, so each local step is
a three-message exchange (DESIGN.md §1):

    1. client:  NanoEdge forward  ->  adapted embeddings E            (up)
    2. server:  frozen-LLM fwd+bwd ->  loss, ∂loss/∂E                 (down)
    3. client:  adapter backward through NanoEdge -> adapter grads    (local)

``jax.vjp`` gives us exactly this factorization: the server half is a VJP of
the backbone loss w.r.t. its *inputs* (never its weights — the backbone stays
frozen); the client half is a VJP of NanoEdge w.r.t. the adapters, seeded
with the server's cotangent. The composition is mathematically identical to
end-to-end ``jax.grad`` over the fused loss (tested in tests/test_split.py),
while every cross-machine tensor is explicit and byte-accounted.

The server step is also the unit that the multi-pod dry-run lowers: a frozen
backbone fwd+bwd over a many-client activation batch.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import adapters as adapters_lib
from repro.core.types import Batch
from repro.models import model as model_lib
from repro.utils import tree_bytes


# ---------------------------------------------------------------------------
# client half
# ---------------------------------------------------------------------------

def client_forward(cfg, backbone_client_side, adapters, batch: Batch):
    """NanoEdge forward. ``backbone_client_side`` holds the frozen pieces the
    client owns (token embedder, connector) — a subset of the server params
    in this simulation, a separate copy on a real device."""
    return adapters_lib.nanoedge_forward(cfg, backbone_client_side, adapters, batch)


def client_forward_vjp(cfg, backbone_client_side, adapters, batch: Batch):
    """Returns (wire activations, vjp closure over the adapters)."""

    def fwd(adp):
        embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
            cfg, backbone_client_side, adp, batch
        )
        wire = (embeds, enc) if enc is not None else (embeds,)
        return wire, (positions, labels, mask)

    wire, vjp_fn, (positions, labels, mask) = jax.vjp(fwd, adapters, has_aux=True)
    embeds = wire[0]
    enc = wire[1] if len(wire) > 1 else None
    return (embeds, positions, labels, mask, enc), vjp_fn


# ---------------------------------------------------------------------------
# server half
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_server_step(cfg) -> Callable:
    """Jitted frozen-backbone fwd+bwd w.r.t. the INPUT activations.

    (backbone, embeds, positions, labels, mask, enc) ->
        (loss, d_embeds, d_enc)
    """

    def server_step(backbone, embeds, positions, labels, mask, enc):
        if enc is not None:
            def f(e, en):
                loss, _ = model_lib.loss_fn(cfg, backbone, e, positions, labels, mask, en)
                return loss

            loss, grads = jax.value_and_grad(f, argnums=(0, 1))(embeds, enc)
            return loss, grads[0], grads[1]

        def f(e):
            loss, _ = model_lib.loss_fn(cfg, backbone, e, positions, labels, mask, None)
            return loss

        loss, d_embeds = jax.value_and_grad(f)(embeds)
        return loss, d_embeds, None

    return jax.jit(server_step, static_argnames=())


# ---------------------------------------------------------------------------
# full split step (simulated exchange, byte-accounted)
# ---------------------------------------------------------------------------

def split_train_grads(cfg, backbone, adapters, batch: Batch):
    """One split-learning gradient computation.

    Returns (loss, adapter_grads, traffic_bytes: dict). Must equal the fused
    ``jax.grad(fednano_loss)`` — the equivalence test for the runtime.
    """
    (embeds, positions, labels, mask, enc), vjp_fn = client_forward_vjp(
        cfg, backbone, adapters, batch
    )
    server_step = make_server_step(cfg)
    loss, d_embeds, d_enc = server_step(backbone, embeds, positions, labels, mask, enc)

    if enc is not None:
        (adapter_grads,) = vjp_fn((d_embeds, d_enc))
        act_up = tree_bytes(embeds) + tree_bytes(enc)
        act_down = tree_bytes(d_embeds) + tree_bytes(d_enc)
    else:
        (adapter_grads,) = vjp_fn((d_embeds,))
        act_up = tree_bytes(embeds)
        act_down = tree_bytes(d_embeds)

    traffic = {"act_up": act_up, "act_down": act_down}
    return loss, adapter_grads, traffic


def split_activation_bytes_per_step(cfg, batch_size: int, seq_len: int,
                                    n_patches: int = None) -> dict:
    """Analytic per-step activation traffic (both directions), bytes.

    Matches the measured ``split_train_grads`` traffic exactly: the wire
    carries the text-token embeddings (B, S, D) PLUS — for any arch with a
    modality frontend — the connected encoder stream (B, M, D), whether it is
    concatenated into the decoder sequence (vlm) or shipped as a separate
    cross-attention memory (audio). ``n_patches`` overrides the per-clip
    patch/frame count (pass 0 for text-only batches on a multimodal arch);
    default is the arch's :func:`~repro.models.vision_stub.num_patches`.
    """
    from repro.models.vision_stub import num_patches

    if n_patches is None:
        n_patches = num_patches(cfg) if cfg.frontend_dim else 0
    itemsize = jnp.dtype(cfg.dtype).itemsize
    act = batch_size * (seq_len + n_patches) * cfg.d_model * itemsize
    return {"act_up": act, "act_down": act}
