"""Heterogeneous NanoAdapter ranks across clients.

Addresses the paper's FIRST stated limitation ("the assumption that all
clients possess similar hardware capabilities … future research could
explore adaptive mechanisms that dynamically adjust NanoAdapter
configurations to fit each client's resource constraints").

Design: client k trains rank-r_k adapters (r_k ≤ R_max); aggregation embeds
every update into the rank-R_max parameter space by zero-padding the extra
rank rows/columns, then Fisher-merges there. Zero-padding is *exactly*
correct for LoRA composition: a rank-r pair (down ∈ D×r, up ∈ r×D) padded to
R produces the identical adapter function (the padded rows of `up` are zero,
so the padded columns of `down` are inert), and its diagonal Fisher is zero
on the padding — Fisher merging then automatically gives those coordinates
zero weight for that client. Each client downloads the merged rank-R
adapters truncated back to its own rank (the leading-R′ sub-pair), i.e. a
server-side rank *projection*.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.aggregation import fisher_merge


def pad_adapter(adapter: Dict, rank_max: int) -> Dict:
    """{'down': (D, r), 'up': (r, D)} -> rank_max-padded pair (same function)."""
    down, up = adapter["down"], adapter["up"]
    r = down.shape[1]
    if r == rank_max:
        return adapter
    assert r < rank_max, (r, rank_max)
    pad = rank_max - r
    return {
        "down": jnp.pad(down, ((0, 0), (0, pad))),
        "up": jnp.pad(up, ((0, pad), (0, 0))),
    }


def truncate_adapter(adapter: Dict, rank: int) -> Dict:
    return {"down": adapter["down"][:, :rank], "up": adapter["up"][:rank, :]}


def pad_nanoedge(adapters: Dict, rank_max: int) -> Dict:
    return {mod: pad_adapter(a, rank_max) for mod, a in adapters.items()}


def truncate_nanoedge(adapters: Dict, rank: int) -> Dict:
    return {mod: truncate_adapter(a, rank) for mod, a in adapters.items()}


def hetero_fisher_merge(
    thetas: List[Dict],
    fishers: List[Dict],
    ranks: Sequence[int],
    data_sizes: Optional[Sequence[float]] = None,
    *,
    rank_max: Optional[int] = None,
):
    """Fisher-merge rank-heterogeneous NanoEdge updates in rank-R_max space.

    fishers may be None per-client (falls back to ones on the client's live
    coordinates — still zero on padding, preserving the correctness above).
    Returns the merged rank-R_max NanoEdge.
    """
    rmax = rank_max or max(ranks)
    padded_t, padded_f = [], []
    for theta, fisher, r in zip(thetas, fishers, ranks):
        padded_t.append(pad_nanoedge(theta, rmax))
        if fisher is None:
            fisher = jax.tree.map(jnp.ones_like, theta)
        padded_f.append(pad_nanoedge(fisher, rmax))
    return fisher_merge(padded_t, padded_f, data_sizes)
