"""Upload compression: int8 quantization of adapter deltas + error feedback.

Beyond-paper extension along the paper's own axis (communication): the
NanoAdapter *delta* (θ_k − θ_global) is what carries information each round;
quantizing it to int8 with per-leaf scales cuts the parameter-plane upload
another 4× below the paper's 0.01 % (fp32 → int8), and the classic error-
feedback accumulator (Seide et al. 2014; Karimireddy et al. 2019) keeps the
compression *unbiased over time*: the residual each round is added back into
the next round's delta before quantization.

Wire format per leaf: int8 payload + one fp32 scale (amortized ≈ 0).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree_sub, tree_add, tree_zeros_like


class QuantizedDelta(NamedTuple):
    payload: Dict    # int8 pytree
    scales: Dict     # fp32 scalars pytree
    base_bytes: int  # bytes of the uncompressed fp32 delta
    wire_bytes: int  # bytes actually on the wire


def _quant_leaf(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_delta(delta) -> QuantizedDelta:
    qs = jax.tree.map(_quant_leaf, delta)
    payload = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    scales = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    from repro.utils import tree_bytes, tree_size

    base = tree_bytes(delta)
    wire = tree_size(delta) * 1 + 4 * len(jax.tree.leaves(scales))
    return QuantizedDelta(payload=payload, scales=scales, base_bytes=base, wire_bytes=wire)


def dequantize_delta(q: QuantizedDelta):
    return jax.tree.map(_dequant_leaf, q.payload, q.scales)


def compress_update(
    adapters, global_ref, error_acc: Optional[Dict] = None
) -> Tuple[QuantizedDelta, Dict, Dict]:
    """Client side: delta = (θ_k − θ_global) + error_feedback; quantize.

    Returns (wire message, new error accumulator, exact reconstruction the
    SERVER will see — useful for tests/aggregation without re-decoding).
    """
    delta = tree_sub(adapters, global_ref)
    if error_acc is not None:
        delta = tree_add(delta, error_acc)
    q = quantize_delta(delta)
    recon = dequantize_delta(q)
    new_error = tree_sub(delta, recon)  # what got lost this round
    return q, new_error, recon


def apply_update(global_ref, recon_delta):
    """Server side: θ_k as seen by the aggregator."""
    return tree_add(global_ref, jax.tree.map(lambda a, b: a.astype(b.dtype) if hasattr(a, "astype") else a, recon_delta, global_ref))


def init_error_feedback(adapters) -> Dict:
    return tree_zeros_like(adapters)
