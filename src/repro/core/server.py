"""Server state: the frozen LLM + global NanoAdapters (Alg. 1, ServerUpdate).

In a real deployment this process owns the TPU mesh; ``repro.launch`` wires
the same functions under pjit. Here the server also performs Fisher-guided
aggregation and tracks communication.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from repro.core import adapters as adapters_lib
from repro.core.comm import CommLog, RoundTraffic
from repro.models import model as model_lib
from repro.utils import tree_bytes


@dataclass
class ServerState:
    cfg: object
    backbone: Dict                  # frozen — never updated after init
    global_adapters: Dict           # current θ_global
    comm: CommLog = field(default_factory=CommLog)
    round_idx: int = 0


def init_server(key, cfg) -> ServerState:
    kb, ka = jax.random.split(key)
    backbone = model_lib.init_backbone(kb, cfg)
    global_adapters = adapters_lib.init_nanoedge(ka, cfg)
    return ServerState(cfg=cfg, backbone=backbone, global_adapters=global_adapters)


def server_commit(
    server: ServerState,
    merged: Optional[Dict],
    *,
    param_up: int,
    fisher_up: int = 0,
    param_down: int = 0,
    wire_up: Optional[int] = None,
) -> ServerState:
    """Install a merged result and log the round's traffic.

    The low-level half of :func:`server_aggregate`, used directly by engines
    that already hold the merged tree (streaming/chunked aggregation and the
    buffered async mode fold uploads incrementally, so the full ``thetas``
    list never exists server-side).
    """
    traffic = RoundTraffic(
        round_idx=server.round_idx,
        param_up=param_up,
        fisher_up=fisher_up,
        param_down=param_down,
        param_up_wire=wire_up if wire_up is not None else param_up,
    )
    comm = server.comm
    comm.log_round(traffic)
    return dataclasses.replace(
        server,
        global_adapters=merged if merged is not None else server.global_adapters,
        comm=comm,
        round_idx=server.round_idx + 1,
    )


def log_downloads(server: ServerState, round_idx: int, down_bytes: int) -> None:
    """Record broadcast traffic for a round with no server aggregation
    (e.g. LocFT's round-0 init download): bytes still crossed the wire."""
    if down_bytes:
        server.comm.log_round(RoundTraffic(round_idx=round_idx, param_down=down_bytes))


def server_aggregate(
    server: ServerState,
    strategy,
    thetas: List[Dict],
    fishers: Optional[List[Dict]],
    data_sizes: List[int],
    *,
    use_pallas: bool = False,
    wire_up: Optional[int] = None,
    down_bytes: Optional[int] = None,
) -> ServerState:
    """Alg. 1 line 7: θ_global <- ServerAgg({θ_k, F_k}).

    ``strategy`` is a registered name or a ``Strategy`` instance; ``wire_up``
    is the transformed upload size in bytes (defaults to the raw fp32 size).
    ``down_bytes`` is what the round's cohort actually pulled from the server
    at round start — the engine passes it so broadcast cost is charged to the
    clients that download, not to this round's uploaders (the two differ
    under partial participation and download-skipping strategies). Without
    it, falls back to the legacy uploader-count estimate.
    """
    from repro.strategies.base import get_strategy

    merged = get_strategy(strategy).aggregate(
        thetas, fishers, data_sizes, use_pallas=use_pallas
    )
    param_up = sum(tree_bytes(t) for t in thetas)
    # a mixed cohort may carry FIMs for only some clients (tree_bytes(None)
    # is 0 via the empty pytree, but gating on fishers[0] miscounted)
    fisher_up = sum(tree_bytes(f) for f in fishers if f is not None) if fishers else 0
    if down_bytes is None:
        down_bytes = tree_bytes(merged) * len(thetas) if merged is not None else 0
    return server_commit(
        server, merged,
        param_up=param_up, fisher_up=fisher_up, param_down=down_bytes,
        wire_up=wire_up,
    )
