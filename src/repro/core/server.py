"""Server state: the frozen LLM + global NanoAdapters (Alg. 1, ServerUpdate).

In a real deployment this process owns the TPU mesh; ``repro.launch`` wires
the same functions under pjit. Here the server also performs Fisher-guided
aggregation and tracks communication.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from repro.core import adapters as adapters_lib
from repro.core.comm import CommLog, RoundTraffic
from repro.models import model as model_lib
from repro.utils import tree_bytes


@dataclass
class ServerState:
    cfg: object
    backbone: Dict                  # frozen — never updated after init
    global_adapters: Dict           # current θ_global
    comm: CommLog = field(default_factory=CommLog)
    round_idx: int = 0


def init_server(key, cfg) -> ServerState:
    kb, ka = jax.random.split(key)
    backbone = model_lib.init_backbone(kb, cfg)
    global_adapters = adapters_lib.init_nanoedge(ka, cfg)
    return ServerState(cfg=cfg, backbone=backbone, global_adapters=global_adapters)


def server_aggregate(
    server: ServerState,
    strategy,
    thetas: List[Dict],
    fishers: Optional[List[Dict]],
    data_sizes: List[int],
    *,
    use_pallas: bool = False,
    wire_up: Optional[int] = None,
) -> ServerState:
    """Alg. 1 line 7: θ_global <- ServerAgg({θ_k, F_k}).

    ``strategy`` is a registered name or a ``Strategy`` instance; ``wire_up``
    is the transformed upload size in bytes (defaults to the raw fp32 size).
    """
    from repro.strategies.base import get_strategy

    merged = get_strategy(strategy).aggregate(
        thetas, fishers, data_sizes, use_pallas=use_pallas
    )
    param_up = sum(tree_bytes(t) for t in thetas)
    traffic = RoundTraffic(
        round_idx=server.round_idx,
        param_up=param_up,
        fisher_up=sum(tree_bytes(f) for f in fishers) if fishers and fishers[0] is not None else 0,
        param_down=tree_bytes(merged) * len(thetas) if merged is not None else 0,
        param_up_wire=wire_up if wire_up is not None else param_up,
    )
    comm = server.comm
    comm.log_round(traffic)
    return dataclasses.replace(
        server,
        global_adapters=merged if merged is not None else server.global_adapters,
        comm=comm,
        round_idx=server.round_idx + 1,
    )
