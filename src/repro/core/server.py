"""Server state: the frozen LLM + global NanoAdapters (Alg. 1, ServerUpdate).

In a real deployment this process owns the TPU mesh; ``repro.launch`` wires
the same functions under pjit. Here the server also performs Fisher-guided
aggregation and tracks communication.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from repro.core import adapters as adapters_lib
from repro.core.aggregation import aggregate
from repro.core.comm import CommLog, RoundTraffic
from repro.models import model as model_lib
from repro.utils import tree_bytes


@dataclass
class ServerState:
    cfg: object
    backbone: Dict                  # frozen — never updated after init
    global_adapters: Dict           # current θ_global
    comm: CommLog = field(default_factory=CommLog)
    round_idx: int = 0


def init_server(key, cfg) -> ServerState:
    kb, ka = jax.random.split(key)
    backbone = model_lib.init_backbone(kb, cfg)
    global_adapters = adapters_lib.init_nanoedge(ka, cfg)
    return ServerState(cfg=cfg, backbone=backbone, global_adapters=global_adapters)


def server_aggregate(
    server: ServerState,
    strategy: str,
    thetas: List[Dict],
    fishers: Optional[List[Dict]],
    data_sizes: List[int],
    *,
    use_pallas: bool = False,
) -> ServerState:
    """Alg. 1 line 7: θ_global <- ServerAgg({θ_k, F_k})."""
    merged = aggregate(strategy, thetas, fishers, data_sizes, use_pallas=use_pallas)
    traffic = RoundTraffic(
        round_idx=server.round_idx,
        param_up=sum(tree_bytes(t) for t in thetas),
        fisher_up=sum(tree_bytes(f) for f in fishers) if fishers and fishers[0] is not None else 0,
        param_down=tree_bytes(merged) * len(thetas) if merged is not None else 0,
    )
    comm = server.comm
    comm.log_round(traffic)
    return dataclasses.replace(
        server,
        global_adapters=merged if merged is not None else server.global_adapters,
        comm=comm,
        round_idx=server.round_idx + 1,
    )
