"""Client failure injection: dropout, mid-update crashes, stragglers.

Production cross-device FL never sees a clean cohort — devices go offline
before a round starts, die mid-update after pulling the global, or finish
late. ``FailureModel`` injects all three into the round engines so
long-horizon runs are testable under churn:

  * **dropout** — the client never starts the round: no download, no
    compute, no upload. The cohort shrinks before any bytes move.
  * **crash (mid-update)** — the client downloads θ_global (those bytes
    crossed the wire and are charged), begins training, then dies: its
    local progress is lost, its persisted ``ClientState`` is untouched
    (``rounds_participated`` does not advance — the process died with its
    memory), and nothing is uploaded.
  * **straggler** — buffered engine only: the client's completion is
    delayed by ``straggler_ticks`` simulated server ticks, so its upload
    arrives stale and is discounted by the FedBuff staleness weight.

Every draw is a pure function of ``(seed, round, cid, kind)`` via the same
``round_key`` derivation the samplers use — no carried RNG state. That makes
failure schedules (a) independent of the training PRNG, so toggling
injection never perturbs a surviving client's trajectory, and (b) exactly
replayable across checkpoint/resume: a resumed run re-derives the identical
drop/crash/straggle pattern for every future round, which is what the
resume-equivalence tests under churn assert.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.strategies.sampling import round_key

# fold_in salts keeping the three draw streams independent per (round, cid)
_KIND_DROP = 0
_KIND_CRASH = 1
_KIND_STRAGGLE = 2


@dataclass(frozen=True)
class FailureModel:
    """Seeded, stateless client-churn model for the round engines.

    ``round_idx`` below is the synchronized round for the sequential/vmap
    engines and the simulated server tick for the buffered engine (async
    clients fail per dispatch attempt, not per merge).
    """

    dropout_prob: float = 0.0     # P(client never starts the round)
    crash_prob: float = 0.0       # P(client dies mid-update after download)
    straggler_prob: float = 0.0   # P(completion delayed; buffered engine)
    straggler_ticks: int = 3      # delay added to a straggling completion
    seed: int = 0

    def __post_init__(self):
        for name in ("dropout_prob", "crash_prob", "straggler_prob"):
            p = getattr(self, name)
            if not 0.0 <= p < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {p}")
        if self.straggler_ticks < 1:
            raise ValueError("straggler_ticks must be >= 1")

    @property
    def active(self) -> bool:
        return (self.dropout_prob > 0.0 or self.crash_prob > 0.0
                or self.straggler_prob > 0.0)

    def _draw(self, kind: int, cid: int, round_idx: int) -> float:
        key = jax.random.fold_in(
            jax.random.fold_in(round_key(self.seed, round_idx), cid), kind)
        return float(jax.random.uniform(key))

    def drops(self, cid: int, round_idx: int) -> bool:
        return (self.dropout_prob > 0.0
                and self._draw(_KIND_DROP, cid, round_idx) < self.dropout_prob)

    def crashes(self, cid: int, round_idx: int) -> bool:
        return (self.crash_prob > 0.0
                and self._draw(_KIND_CRASH, cid, round_idx) < self.crash_prob)

    def straggles(self, cid: int, round_idx: int) -> bool:
        return (self.straggler_prob > 0.0
                and self._draw(_KIND_STRAGGLE, cid, round_idx)
                < self.straggler_prob)

    def to_dict(self) -> dict:
        """JSON-safe form recorded in RunState meta (resume sanity check)."""
        import dataclasses

        return dataclasses.asdict(self)
