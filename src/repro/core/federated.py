"""Federated orchestration — Alg. 1 of the paper as a strategy-agnostic engine.

``run_federated`` is a thin loop over the ``repro.strategies`` hooks:

    sampler.select          -> which clients run this round
    client.local_update     -> T local steps via the strategy's loss/fisher hooks
    strategy.post_local_update -> what each client offers for upload
    transforms[*].apply     -> DP / quantization / sparsification on the wire
    strategy.aggregate      -> merge (via server.server_aggregate, which logs comm)
    server_opt.apply        -> optional FedOpt step on the merged pseudo-gradient
    strategy.eval_params    -> which params each client evaluates at the end

Methods are plugins (``repro.strategies``): the engine never branches on a
strategy name. Strings like ``strategy="fednano"`` resolve through the
registry, so the legacy API keeps working.

Three execution engines share those hooks:

  * ``engine="sequential"`` — one client at a time, a Python loop of jitted
    steps. Reference semantics; handles ragged per-client data.
  * ``engine="vmap"`` — the round's cohort is grouped by scheduling flags,
    per-client state pytrees are stacked, and each group runs as ``vmap``
    (clients) of ``lax.scan`` (local steps): one dispatch per group instead
    of K·T. Seeded metrics match the sequential engine (pinned against
    ``tests/golden/strategy_parity.json``). With ``agg_chunk=c`` the cohort
    is processed in chunks of ``c`` and folded into a running merge through
    the strategy's ``agg_stream_*`` hooks, so server memory is O(c) in the
    cohort size.
  * ``engine="buffered"`` — FedBuff-style async simulation: clients run
    against the global version they last downloaded, a completion-ordered
    event loop fills a server buffer, and every ``buffer_size`` arrivals are
    merged with staleness-discounted weights n_k/(1+τ)^p. Stragglers delay
    only their own upload, never the round.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax

from repro.core import client as client_lib
from repro.core import server as server_lib
from repro.core.client import ClientState, HyperParams
from repro.core.comm import RoundTraffic
from repro.core.types import Batch
from repro.strategies.base import Strategy, get_strategy
from repro.strategies.sampling import ClientSampler
from repro.strategies.server_opt import ServerOpt
from repro.strategies.transforms import (
    TransformCtx,
    UpdateTransform,
    default_transforms,
)
from repro.utils import tree_bytes

ENGINES = ("sequential", "vmap", "buffered")


@dataclass
class FederatedResult:
    strategy: str
    round_metrics: List[Dict] = field(default_factory=list)
    client_accuracy: Dict[int, float] = field(default_factory=dict)
    avg_accuracy: float = 0.0
    comm_totals: Dict[str, int] = field(default_factory=dict)
    server: Optional[object] = None
    clients: Optional[List[ClientState]] = None
    engine: str = "sequential"


def run_federated(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    strategy: Union[str, Strategy] = "fednano",
    rounds: int = 10,
    hp: HyperParams = HyperParams(),
    use_pallas: bool = False,
    server: Optional[server_lib.ServerState] = None,
    verbose: bool = False,
    transforms: Optional[Sequence[UpdateTransform]] = None,
    server_opt: Optional[ServerOpt] = None,
    sampler: Optional[ClientSampler] = None,
    engine: str = "sequential",
    agg_chunk: Optional[int] = None,
    buffer_size: Optional[int] = None,
    staleness_power: float = 0.5,
    latency_fn: Optional[Callable[[int, int], int]] = None,
    final_eval: bool = True,
) -> FederatedResult:
    """Run R rounds of federated NanoAdapter tuning.

    ``transforms`` defaults to the ``hp``-driven chain (DP, then int8+EF);
    ``server_opt`` defaults to the strategy's own (usually None = identity);
    ``sampler`` defaults to full participation. ``engine`` picks the
    execution path (see module docstring); ``agg_chunk`` bounds server-side
    aggregation memory by folding cohort chunks through the strategy's
    streaming-merge hooks. ``buffer_size`` / ``staleness_power`` /
    ``latency_fn(cid, version) -> int`` configure the buffered async engine
    (``rounds`` then counts server merges, not synchronized rounds).
    ``final_eval=False`` skips the end-of-run accuracy pass (benchmarks
    timing 10k-client rounds don't want 10k eval dispatches).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    strat = get_strategy(strategy)
    if transforms is None:
        transforms = default_transforms(hp)
    if server_opt is None:
        server_opt = strat.server_opt()
    if sampler is None:
        sampler = ClientSampler()

    k_server, k_clients = jax.random.split(key)
    if server is None:
        server = server_lib.init_server(k_server, cfg)
    cids = sorted(train_data)
    index_of = {cid: i for i, cid in enumerate(cids)}
    ckeys = jax.random.split(k_clients, len(cids))
    clients = [
        strat.init_client(ck, cfg, cid, n_examples=len(train_data[cid]))
        for ck, cid in zip(ckeys, cids)
    ]
    tstates = {cid: [None] * len(transforms) for cid in cids}

    if engine == "buffered":
        result, server = _run_buffered(
            cfg, server, strat, clients, cids, index_of, train_data, hp,
            transforms, tstates, server_opt, rounds=rounds,
            buffer_size=buffer_size, staleness_power=staleness_power,
            latency_fn=latency_fn, use_pallas=use_pallas, verbose=verbose,
        )
    else:
        result, server = _run_sync(
            cfg, server, strat, clients, cids, index_of, train_data, hp,
            transforms, tstates, server_opt, sampler, rounds=rounds,
            engine=engine, agg_chunk=agg_chunk, use_pallas=use_pallas,
            verbose=verbose,
        )

    # final evaluation: every client, on the params its strategy designates
    # (global adapters for most; LocFT/FedDPA-F evaluate personalized params).
    if final_eval:
        for cid in cids:
            adp, ladp = strat.eval_params(server.global_adapters, clients[index_of[cid]])
            acc = client_lib.eval_client(cfg, server.backbone, adp, ladp, eval_data[cid])
            result.client_accuracy[cid] = acc
        result.avg_accuracy = (
            sum(result.client_accuracy.values()) / max(len(cids), 1)
        )
    result.comm_totals = server.comm.totals()
    result.server = server
    result.clients = clients
    return result


def _chunks(seq: List, width: int):
    for i in range(0, len(seq), width):
        yield seq[i : i + width]


def _run_sync(
    cfg, server, strat, clients, cids, index_of, train_data, hp,
    transforms, tstates, server_opt, sampler, *, rounds, engine, agg_chunk,
    use_pallas, verbose,
):
    """Synchronized rounds: ``engine`` is "sequential" or "vmap"."""
    streaming = bool(agg_chunk) and strat.aggregates
    opt_state = server_opt.init(server.global_adapters) if server_opt else None
    result = FederatedResult(strategy=strat.name, engine=engine)

    for r in range(rounds):
        cohort = list(sampler.select(r, cids))
        gbytes = tree_bytes(server.global_adapters)
        down_bytes = 0
        wire_up = 0
        losses: List[float] = []           # cohort order
        updates: List[tuple] = []          # (theta, fisher, size), cohort order
        stream_acc = strat.agg_stream_init() if streaming else None
        stream_buf: List[tuple] = []
        stream_bytes = {"param_up": 0, "fisher_up": 0}
        folded_any = False

        def apply_transforms(cid: int, theta):
            ctx = TransformCtx(cid=cid, round_idx=r)
            theta_wire = None
            for j, t in enumerate(transforms):
                theta, tstates[cid][j], w = t.apply(
                    ctx, theta, server.global_adapters, tstates[cid][j]
                )
                if w is not None:
                    theta_wire = w
            return theta, (theta_wire if theta_wire is not None else tree_bytes(theta))

        def offer(cid: int, state: ClientState, loss_mean: float):
            nonlocal wire_up, folded_any
            theta = strat.post_local_update(state, server.global_adapters, r)
            theta, wbytes = apply_transforms(cid, theta)
            wire_up += wbytes
            losses.append(loss_mean)
            if streaming:
                stream_buf.append((theta, state.fisher, state.n_examples))
                if len(stream_buf) >= agg_chunk:
                    fold_stream()
            else:
                updates.append((theta, state.fisher, state.n_examples))

        def fold_stream():
            nonlocal stream_acc, folded_any
            if not stream_buf:
                return
            ts = [u[0] for u in stream_buf]
            fs = [u[1] for u in stream_buf]
            ws = [u[2] for u in stream_buf]
            stream_bytes["param_up"] += sum(tree_bytes(t) for t in ts)
            stream_bytes["fisher_up"] += sum(
                tree_bytes(f) for f in fs if f is not None)
            stream_acc = strat.agg_stream_fold(
                stream_acc, ts, fs, ws, use_pallas=use_pallas)
            folded_any = True
            stream_buf.clear()

        if engine == "sequential":
            for cid in cohort:
                i = index_of[cid]
                if strat.downloads_global(clients[i].rounds_participated):
                    down_bytes += gbytes
                clients[i], metrics = client_lib.local_update(
                    cfg, server.backbone, clients[i], train_data[cid], hp,
                    strat, server.global_adapters, round_idx=r,
                )
                offer(cid, clients[i], metrics["loss_mean"])
        else:  # engine == "vmap": group cohort by scheduling flags, then batch
            groups: Dict[tuple, List[int]] = {}
            for cid in cohort:
                st = clients[index_of[cid]]
                p = st.rounds_participated
                flags = (
                    strat.downloads_global(p),
                    st.local_adapters is not None and strat.local_warmup(p, hp),
                )
                groups.setdefault(flags, []).append(cid)
            # non-streaming aggregation must see cohort order; buffer per-cid
            pending: Dict[int, tuple] = {}
            for (downloads, _), gcids in groups.items():
                width = agg_chunk if agg_chunk else len(gcids)
                for chunk in _chunks(gcids, width):
                    idxs = [index_of[c] for c in chunk]
                    new_states, mets = client_lib.local_update_many(
                        cfg, server.backbone, [clients[i] for i in idxs],
                        [train_data[c] for c in chunk], hp, strat,
                        server.global_adapters,
                    )
                    if downloads:
                        down_bytes += gbytes * len(chunk)
                    for c, i, ns, m in zip(chunk, idxs, new_states, mets):
                        clients[i] = ns
                        pending[c] = m["loss_mean"]
                        offer(c, ns, m["loss_mean"])
            # keep round metrics in cohort order regardless of grouping
            losses = [pending[c] for c in cohort if c in pending]

        if strat.aggregates and (updates or stream_buf or folded_any):
            prev_global = server.global_adapters
            if streaming:
                fold_stream()
                merged = strat.agg_stream_finalize(stream_acc, use_pallas=use_pallas)
                server = server_lib.server_commit(
                    server, merged,
                    param_up=stream_bytes["param_up"],
                    fisher_up=stream_bytes["fisher_up"],
                    param_down=down_bytes, wire_up=wire_up,
                )
            else:
                thetas = [u[0] for u in updates]
                fishers = [u[1] for u in updates]
                sizes = [u[2] for u in updates]
                server = server_lib.server_aggregate(
                    server, strat, thetas, fishers, sizes,
                    use_pallas=use_pallas, wire_up=wire_up,
                    down_bytes=down_bytes,
                )
            if server_opt is not None:
                new_global, opt_state = server_opt.apply(
                    opt_state, prev_global, server.global_adapters
                )
                server = dataclasses.replace(server, global_adapters=new_global)
        elif down_bytes:
            # no merge this round (e.g. LocFT) but clients still pulled the
            # global at round start — that broadcast crossed the wire
            server_lib.log_downloads(server, r, down_bytes)

        n = len(losses)
        # an empty cohort must be distinguishable from a perfect round:
        # participants==0 carries mean_loss=None, never a fake 0.0
        rm = {"round": r,
              "mean_loss": (sum(losses) / n) if n else None,
              "participants": n}
        result.round_metrics.append(rm)
        if verbose:
            shown = "skipped (no participants)" if n == 0 else f"mean local loss {rm['mean_loss']:.4f}"
            print(f"  [{strat.name}] round {r}: {shown}")

    return result, server


def _run_buffered(
    cfg, server, strat, clients, cids, index_of, train_data, hp,
    transforms, tstates, server_opt, *, rounds, buffer_size, staleness_power,
    latency_fn, use_pallas, verbose,
):
    """FedBuff-style async engine: merge every ``buffer_size`` completions.

    Simulated time advances in integer server ticks; ``latency_fn(cid,
    version)`` says how many ticks a client's local run takes (default 1 —
    homogeneous clients degenerate to synchronized rounds). A client always
    trains against the global *version it last downloaded*; its upload is
    merged with weight n_k/(1+τ)^p where τ is the number of server merges
    that happened while it was running. ``rounds`` counts server merges.
    """
    if not strat.aggregates:
        raise ValueError(
            f"engine='buffered' needs an aggregating strategy; {strat.name!r} "
            "never merges (local-only)")
    bsize = buffer_size if buffer_size else max(1, len(cids) // 2)
    bsize = min(bsize, len(cids))
    if latency_fn is None:
        latency_fn = lambda cid, version: 1  # noqa: E731
    opt_state = server_opt.init(server.global_adapters) if server_opt else None
    result = FederatedResult(strategy=strat.name, engine="buffered")
    gbytes = tree_bytes(server.global_adapters)

    # version -> [global snapshot, in-flight refcount]; clients in flight pin
    # the snapshot they downloaded, so memory is O(distinct live versions)
    version = 0
    snapshots: Dict[int, list] = {version: [server.global_adapters, 0]}
    events: List[tuple] = []  # (finish_tick, cid, version_started)
    merges = 0
    acc_up = {"param_up": 0, "fisher_up": 0, "wire_up": 0, "down": 0}
    buffer: List[tuple] = []  # (theta, fisher, size, loss_mean, staleness)

    def dispatch(cid: int, now: int):
        st = clients[index_of[cid]]
        if strat.downloads_global(st.rounds_participated):
            acc_up["down"] += gbytes
        snapshots[version][1] += 1
        lat = max(1, int(latency_fn(cid, version)))
        heapq.heappush(events, (now + lat, cid, version))

    for cid in cids:
        dispatch(cid, 0)

    while merges < rounds:
        # drain every completion in this simulated tick before re-dispatching
        # any of them: a client re-downloads only after its upload is acked,
        # by which point the server has folded everything this tick produced
        # (so uniform latency degenerates to synchronized zero-staleness
        # rounds instead of racing re-downloads against the merge)
        now = events[0][0]
        done_this_tick: List[int] = []
        while events and events[0][0] == now and merges < rounds:
            _, cid, v_start = heapq.heappop(events)
            done_this_tick.append(cid)
            snap_global, _ = snapshots[v_start]
            i = index_of[cid]
            clients[i], metrics = client_lib.local_update(
                cfg, server.backbone, clients[i], train_data[cid], hp, strat,
                snap_global, round_idx=merges,
            )
            theta = strat.post_local_update(clients[i], snap_global, merges)
            ctx = TransformCtx(cid=cid, round_idx=merges)
            theta_wire = None
            for j, t in enumerate(transforms):
                theta, tstates[cid][j], w = t.apply(ctx, theta, snap_global,
                                                    tstates[cid][j])
                if w is not None:
                    theta_wire = w
            acc_up["wire_up"] += theta_wire if theta_wire is not None else tree_bytes(theta)
            acc_up["param_up"] += tree_bytes(theta)
            if clients[i].fisher is not None:
                acc_up["fisher_up"] += tree_bytes(clients[i].fisher)
            buffer.append((theta, clients[i].fisher, clients[i].n_examples,
                           metrics["loss_mean"], version - v_start))
            snapshots[v_start][1] -= 1
            if snapshots[v_start][1] == 0 and v_start != version:
                del snapshots[v_start]

            if len(buffer) >= bsize:
                weights = [n / (1.0 + tau) ** staleness_power
                           for _, _, n, _, tau in buffer]
                sacc = strat.agg_stream_init()
                sacc = strat.agg_stream_fold(
                    sacc, [b[0] for b in buffer], [b[1] for b in buffer], weights,
                    use_pallas=use_pallas)
                merged = strat.agg_stream_finalize(sacc, use_pallas=use_pallas)
                prev_global = server.global_adapters
                server = server_lib.server_commit(
                    server, merged,
                    param_up=acc_up["param_up"], fisher_up=acc_up["fisher_up"],
                    param_down=acc_up["down"], wire_up=acc_up["wire_up"],
                )
                if server_opt is not None:
                    new_global, opt_state = server_opt.apply(
                        opt_state, prev_global, server.global_adapters)
                    server = dataclasses.replace(server, global_adapters=new_global)
                blosses = [b[3] for b in buffer]
                bstale = [b[4] for b in buffer]
                rm = {"round": merges,
                      "mean_loss": sum(blosses) / len(blosses),
                      "participants": len(buffer),
                      "mean_staleness": sum(bstale) / len(bstale)}
                result.round_metrics.append(rm)
                if verbose:
                    print(f"  [{strat.name}] merge {merges}: mean loss "
                          f"{rm['mean_loss']:.4f} staleness {rm['mean_staleness']:.2f}")
                merges += 1
                version += 1
                snapshots[version] = [server.global_adapters, 0]
                buffer.clear()
                acc_up = {"param_up": 0, "fisher_up": 0, "wire_up": 0, "down": 0}

        for cid in done_this_tick:
            dispatch(cid, now)

    return result, server


def run_centralized(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    steps: int = 100,
    hp: HyperParams = HyperParams(),
    verbose: bool = False,
) -> FederatedResult:
    """Upper bound: one 'client' holding the union of all data."""
    all_train: List[Batch] = []
    for cid in sorted(train_data):
        all_train.extend(train_data[cid])
    k_server, k_client = jax.random.split(key)
    server = server_lib.init_server(k_server, cfg)
    state = client_lib.init_client(
        k_client, cfg, cid=0, n_examples=len(all_train), strategy="fedavg"
    )
    hp_c = HyperParams(
        lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        local_steps=steps, prox_mu=hp.prox_mu, fisher_batches=hp.fisher_batches,
    )
    state, metrics = client_lib.local_update(
        cfg, server.backbone, state, all_train, hp_c, "fedavg",
        server.global_adapters, round_idx=0,
    )
    result = FederatedResult(strategy="centralized")
    result.round_metrics.append(
        {"round": 0, "mean_loss": metrics["loss_mean"], "participants": 1}
    )
    # the centralized upper bound still moves bytes: one initial broadcast
    # down to the lone trainer, one adapter upload back — log it so comm
    # tables comparing against this bound don't silently read zeros
    server.comm.log_round(RoundTraffic(
        round_idx=0,
        param_up=tree_bytes(state.adapters),
        param_down=tree_bytes(server.global_adapters),
        param_up_wire=tree_bytes(state.adapters),
    ))
    for cid in sorted(eval_data):
        acc = client_lib.eval_client(cfg, server.backbone, state.adapters, None, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(result.client_accuracy)
    result.comm_totals = server.comm.totals()
    result.server = server
    result.clients = [state]
    if verbose:
        print(f"  [centralized] acc {result.avg_accuracy:.4f}")
    return result
