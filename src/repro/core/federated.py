"""Federated orchestration — Alg. 1 of the paper as a strategy-agnostic engine.

``run_federated`` is a thin loop over the ``repro.strategies`` hooks:

    sampler.select          -> which clients run this round
    client.local_update     -> T local steps via the strategy's loss/fisher hooks
    strategy.post_local_update -> what each client offers for upload
    transforms[*].apply     -> DP / quantization / sparsification on the wire
    strategy.aggregate      -> merge (via server.server_aggregate, which logs comm)
    server_opt.apply        -> optional FedOpt step on the merged pseudo-gradient
    strategy.eval_params    -> which params each client evaluates at the end

Methods are plugins (``repro.strategies``): the engine never branches on a
strategy name. Strings like ``strategy="fednano"`` resolve through the
registry, so the legacy API keeps working.

Four execution engines share those hooks:

  * ``engine="sequential"`` — one client at a time, a Python loop of jitted
    steps. Reference semantics; handles ragged per-client data.
  * ``engine="vmap"`` — the round's cohort is grouped by scheduling flags,
    per-client state pytrees are stacked, and each group runs as ``vmap``
    (clients) of ``lax.scan`` (local steps): one dispatch per group instead
    of K·T. Seeded metrics match the sequential engine (pinned against
    ``tests/golden/strategy_parity.json``). With ``agg_chunk=c`` the cohort
    is processed in chunks of ``c`` and folded into a running merge through
    the strategy's ``agg_stream_*`` hooks, so server memory is O(c) in the
    cohort size.
  * ``engine="sharded"`` — the vmap layout partitioned over a 1-D
    ``("clients",)`` device mesh (``repro.sharding.client_mesh``): the same
    stacked cohorts are wrapped in ``shard_map`` so each of D devices runs
    K/D clients in parallel with unchanged per-client arithmetic (seeded
    metrics match ``engine="vmap"``). Cohorts that don't divide D are
    padded by repeating the last client's row; padding rows never reach
    aggregation, metrics, or comm accounting. With ``overlap=True`` the
    engine keeps a two-deep dispatch pipeline — host-side stack/unstack of
    cohort k+1 overlaps device compute of cohort k (JAX dispatch is async;
    the blocking ``device_get`` happens one cohort late). Cohorts are
    dispatched in cache-sized chunks (width ≤ ``_CHUNK_WIDTH_CAP``), chunk
    state stays device-resident across rounds (stacked outputs feed the
    next round's dispatch directly; ``materialize`` writes true rows back
    before checkpoints, reshuffles, or run end), placed batch stacks are
    cached per chunk, and — when every upload is the raw adapter tree —
    aggregation runs device-side: all chunk outputs fold into the merge in
    one fused dispatch per round (padding rows zero-weighted), with losses
    gathered in a single batched ``device_get``.
  * ``engine="buffered"`` — FedBuff-style async simulation: clients run
    against the global version they last downloaded, a completion-ordered
    event loop fills a server buffer, and every ``buffer_size`` arrivals are
    merged with staleness-discounted weights n_k/(1+τ)^p. Stragglers delay
    only their own upload, never the round. ``failures=`` draws are wired
    into each dispatch attempt: dropped clients never enqueue an upload,
    crashed clients lose their local progress, stragglers complete with
    extra staleness — all counted per merge in round metrics and carried in
    checkpoints so resume-replay stays deterministic.

Fault tolerance rides on the same loop: ``checkpoint_dir`` periodically
snapshots the *entire* round state (``repro.checkpoint.RunState``: θ_global,
ServerOpt moments, every client's AdamW/warmup state, transform residuals,
CommLog, round RNG identity, and the buffered engine's event queue +
version refcounts), ``resume=`` restores one and replays deterministically
— a resumed run's metrics equal the uninterrupted run's — and
``failures=FailureModel(...)`` injects seeded client dropout, mid-update
crashes, and stragglers so long-horizon runs are testable under churn.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint import (
    BufferedState,
    CheckpointError,
    RunState,
    load_run_state,
    read_run_meta,
    resolve_run_state_dir,
    save_run_state,
)
from repro.checkpoint.io import _key_data
from repro.core import client as client_lib
from repro.core import server as server_lib
from repro.core.client import ClientState, HyperParams
from repro.core.comm import CommLog, RoundTraffic
from repro.core.failures import FailureModel
from repro.core.types import Batch
from repro.strategies.base import Strategy, get_strategy
from repro.strategies.sampling import ClientSampler
from repro.strategies.server_opt import ServerOpt
from repro.strategies.transforms import (
    TransformCtx,
    UpdateTransform,
    default_transforms,
)
from repro.utils import tree_bytes

ENGINES = ("sequential", "vmap", "sharded", "buffered")

# without agg_chunk, the sharded engine splits each flag-group into at least
# this many dispatch chunks (rounded up to a multiple of the mesh size) so
# the double buffer has successive launches to overlap — and caps the chunk
# width at _CHUNK_WIDTH_CAP so each dispatch's working set stays cache-sized
# no matter how large the cohort grows (empirically the larger lever on CPU
# meshes: the per-1k-clients step cost is flat for widths 32–128 and ~35%
# worse by width 256, so a 10k cohort runs as ~80 width-128 chunks rather
# than 16 width-632 ones); dispatch width never changes aggregation
# numerics — offers are buffered per client and folded at agg_chunk
# boundaries regardless of how cohorts were batched on device
_PIPELINE_CHUNKS = 16
_CHUNK_WIDTH_CAP = 128

# buffered-engine event kinds: RUN completes a local update; RETRY is a
# failed attempt (dropout/crash) coming back for re-dispatch
_EV_RUN = 0
_EV_RETRY = 1


@dataclass
class FederatedResult:
    strategy: str
    round_metrics: List[Dict] = field(default_factory=list)
    client_accuracy: Dict[int, float] = field(default_factory=dict)
    avg_accuracy: float = 0.0
    comm_totals: Dict[str, int] = field(default_factory=dict)
    server: Optional[object] = None
    clients: Optional[List[ClientState]] = None
    engine: str = "sequential"
    server_opt_state: Optional[object] = None  # final ServerOpt moments
                                               # (checkpointable; see
                                               # save_server_checkpoint)
    setup_s: float = 0.0          # wall seconds spent initializing clients
                                  # (batched vs per-client; engine_bench rows)


class _Checkpointer:
    """Writes versioned RunState snapshots under ``dirpath``.

    Each snapshot lands in ``round_<n>/`` and ``LATEST`` is updated after a
    successful save, so ``resume=<dirpath>`` picks up the newest complete
    one even if the process died mid-write (a snapshot without its
    meta.json — written last — is invisible to the resolver).
    """

    def __init__(self, dirpath: str, every: int, *, key, engine: str,
                 strat, hp, cfg, cids, transforms, failures,
                 start: int = 0):
        self.dirpath = dirpath
        self.every = every
        self.engine = engine
        self.strat = strat
        self.cids = list(cids)
        self.transforms = transforms
        self._last = start
        self._key_data = _key_data(key)
        self._meta_extra = {
            "cfg_name": cfg.name,
            "hp": dataclasses.asdict(hp),
            "strategy_meta": strat.checkpoint_meta(),
            "transforms": [type(t).__name__ for t in transforms],
            "failure_model": failures.to_dict() if failures is not None else None,
        }

    def would_save(self, n: int) -> bool:
        return self.every > 0 and n > self._last and n % self.every == 0

    def maybe_save(self, n: int, **kw) -> None:
        if self.would_save(n):
            self.save(n, **kw)

    def final_save(self, n: int, **kw) -> None:
        if n > self._last:
            self.save(n, **kw)

    def save(self, n: int, *, server, clients, tstates, opt_state,
             metrics, buffered: Optional[BufferedState] = None) -> None:
        rs = RunState(
            engine=self.engine,
            strategy=self.strat.name,
            round_idx=n,
            server_round_idx=server.round_idx,
            rng_key=self._key_data,
            global_adapters=server.global_adapters,
            server_opt_state=opt_state,
            clients=list(clients),
            tstates=[list(tstates[cid]) for cid in self.cids],
            round_metrics=list(metrics),
            comm_rounds=server.comm.state_dict(),
            buffered=buffered,
            meta_extra=self._meta_extra,
        )
        sub = f"round_{n:06d}"
        save_run_state(os.path.join(self.dirpath, sub), rs)
        with open(os.path.join(self.dirpath, "LATEST"), "w") as f:
            f.write(sub)
        self._last = n


def _load_resume(resume: str, *, key, engine, strat, hp, cfg, server,
                 clients, server_opt, transforms) -> RunState:
    """Restore + validate a RunState against this run's configuration.

    Resume means *deterministic replay*: the checkpoint must have been
    written by a run with the same seed, config, strategy, hyperparameters,
    engine, and transform chain — anything else is a fork, and forks should
    go through explicit state surgery, not a resume flag.
    """
    dirpath = resolve_run_state_dir(resume)
    meta = read_run_meta(dirpath)

    def bail(what, saved, current):
        raise CheckpointError(
            f"cannot resume from {dirpath!r}: checkpoint {what} is "
            f"{saved!r}, this run uses {current!r} — resuming would not "
            "replay the original run (start a fresh run or convert the "
            "checkpoint explicitly)")

    if meta["engine"] != engine:
        bail("engine", meta["engine"], engine)
    if meta.get("strategy_meta") != strat.checkpoint_meta():
        bail("strategy", meta.get("strategy_meta"), strat.checkpoint_meta())
    if meta.get("cfg_name") != cfg.name:
        bail("config", meta.get("cfg_name"), cfg.name)
    if meta.get("hp") != dataclasses.asdict(hp):
        bail("hyperparameters", meta.get("hp"), dataclasses.asdict(hp))
    tnames = [type(t).__name__ for t in transforms]
    if meta.get("transforms") != tnames:
        bail("transform chain", meta.get("transforms"), tnames)

    rs = load_run_state(
        dirpath,
        clients_ref=clients,
        global_ref=server.global_adapters,
        server_opt_state_ref=(server_opt.init(server.global_adapters)
                              if server_opt is not None else None),
        transform_templates=[t.state_template(server.global_adapters)
                             for t in transforms],
    )
    kd = _key_data(key)
    if not np.array_equal(np.asarray(rs.rng_key), np.asarray(kd)):
        raise CheckpointError(
            f"cannot resume from {dirpath!r}: the checkpoint was written "
            "under a different root PRNG key — the frozen backbone and "
            "client init are re-derived from the seed at resume, so the "
            "same key/seed is required for faithful replay")
    return rs


def run_federated(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    strategy: Union[str, Strategy] = "fednano",
    rounds: int = 10,
    hp: HyperParams = HyperParams(),
    use_pallas: bool = False,
    server: Optional[server_lib.ServerState] = None,
    verbose: bool = False,
    transforms: Optional[Sequence[UpdateTransform]] = None,
    server_opt: Optional[ServerOpt] = None,
    sampler: Optional[ClientSampler] = None,
    engine: str = "sequential",
    agg_chunk: Optional[int] = None,
    devices: Optional[int] = None,
    overlap: bool = True,
    buffer_size: Optional[int] = None,
    staleness_power: float = 0.5,
    latency_fn: Optional[Callable[[int, int], int]] = None,
    final_eval: bool = True,
    failures: Optional[FailureModel] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    resume: Optional[str] = None,
) -> FederatedResult:
    """Run R rounds of federated NanoAdapter tuning.

    ``transforms`` defaults to the ``hp``-driven chain (DP, then int8+EF);
    ``server_opt`` defaults to the strategy's own (usually None = identity);
    ``sampler`` defaults to full participation. ``engine`` picks the
    execution path (see module docstring); ``agg_chunk`` bounds server-side
    aggregation memory by folding cohort chunks through the strategy's
    streaming-merge hooks. ``devices`` (sharded engine only) caps the mesh
    at the first N local devices (default: all — on CPU force a topology
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
    ``overlap=False`` disables the sharded engine's two-deep
    prepare/compute double buffer (for benchmarking the overlap win).
    ``buffer_size`` / ``staleness_power`` /
    ``latency_fn(cid, version) -> int`` configure the buffered async engine
    (``rounds`` then counts server merges, not synchronized rounds).
    ``final_eval=False`` skips the end-of-run accuracy pass (benchmarks
    timing 10k-client rounds don't want 10k eval dispatches).

    Fault tolerance: ``failures`` injects seeded client churn (see
    :class:`repro.core.failures.FailureModel`); ``checkpoint_dir`` +
    ``checkpoint_every=k`` snapshot the full round state every k rounds
    (merges, for the buffered engine) plus once at run end (``k=0`` keeps
    only the final snapshot); ``resume=<dir>`` restores a snapshot — pass
    the same key/cfg/hp/strategy and the run replays exactly where it left
    off, with metrics and comm totals matching an uninterrupted run.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    if devices is not None and engine != "sharded":
        raise ValueError("devices= only applies to engine='sharded'")
    mesh = None
    if engine == "sharded":
        from repro.sharding import client_mesh

        mesh = client_mesh(devices)
    strat = get_strategy(strategy)
    if transforms is None:
        transforms = default_transforms(hp)
    if server_opt is None:
        server_opt = strat.server_opt()
    if sampler is None:
        sampler = ClientSampler()

    k_server, k_clients = jax.random.split(key)
    if server is None:
        server = server_lib.init_server(k_server, cfg)
    cids = sorted(train_data)
    index_of = {cid: i for i, cid in enumerate(cids)}
    ckeys = jax.random.split(k_clients, len(cids))
    t0 = time.perf_counter()
    # batched vmapped init when the strategy uses the stock client layout;
    # bit-identical to the per-client loop (counter-based PRNG), which
    # strategies with custom/ragged state fall back to automatically
    clients = strat.init_clients(
        ckeys, cfg, cids, [len(train_data[cid]) for cid in cids])
    setup_s = time.perf_counter() - t0
    tstates = {cid: [None] * len(transforms) for cid in cids}

    resume_state = None
    if resume is not None:
        resume_state = _load_resume(
            resume, key=key, engine=engine, strat=strat, hp=hp, cfg=cfg,
            server=server, clients=clients, server_opt=server_opt,
            transforms=transforms)
        server = dataclasses.replace(
            server,
            global_adapters=resume_state.global_adapters,
            comm=CommLog.from_state_dict(resume_state.comm_rounds),
            round_idx=resume_state.server_round_idx,
        )
        clients[:] = resume_state.clients
        for i, cid in enumerate(cids):
            tstates[cid] = list(resume_state.tstates[i])
        if verbose:
            print(f"  [{strat.name}] resumed at "
                  f"{'merge' if engine == 'buffered' else 'round'} "
                  f"{resume_state.round_idx} from {resume}")

    ckpt = None
    if checkpoint_dir:
        ckpt = _Checkpointer(
            checkpoint_dir, checkpoint_every, key=key, engine=engine,
            strat=strat, hp=hp, cfg=cfg, cids=cids, transforms=transforms,
            failures=failures,
            start=resume_state.round_idx if resume_state is not None else 0)

    if engine == "buffered":
        result, server = _run_buffered(
            cfg, server, strat, clients, cids, index_of, train_data, hp,
            transforms, tstates, server_opt, rounds=rounds,
            buffer_size=buffer_size, staleness_power=staleness_power,
            latency_fn=latency_fn, use_pallas=use_pallas, verbose=verbose,
            failures=failures, ckpt=ckpt, resume_state=resume_state,
        )
    else:
        result, server = _run_sync(
            cfg, server, strat, clients, cids, index_of, train_data, hp,
            transforms, tstates, server_opt, sampler, rounds=rounds,
            engine=engine, agg_chunk=agg_chunk, use_pallas=use_pallas,
            verbose=verbose, failures=failures, ckpt=ckpt,
            resume_state=resume_state, mesh=mesh, overlap=overlap,
        )
    result.setup_s = setup_s

    # final evaluation: every client, on the params its strategy designates
    # (global adapters for most; LocFT/FedDPA-F evaluate personalized params).
    if final_eval:
        for cid in cids:
            adp, ladp = strat.eval_params(server.global_adapters, clients[index_of[cid]])
            acc = client_lib.eval_client(cfg, server.backbone, adp, ladp, eval_data[cid])
            result.client_accuracy[cid] = acc
        result.avg_accuracy = (
            sum(result.client_accuracy.values()) / max(len(cids), 1)
        )
    result.comm_totals = server.comm.totals()
    result.server = server
    result.clients = clients
    return result


def _chunks(seq: List, width: int):
    for i in range(0, len(seq), width):
        yield seq[i : i + width]


def _run_sync(
    cfg, server, strat, clients, cids, index_of, train_data, hp,
    transforms, tstates, server_opt, sampler, *, rounds, engine, agg_chunk,
    use_pallas, verbose, failures=None, ckpt=None, resume_state=None,
    mesh=None, overlap=True,
):
    """Synchronized rounds: ``engine`` is "sequential", "vmap" or "sharded"."""
    streaming = bool(agg_chunk) and strat.aggregates
    opt_state = server_opt.init(server.global_adapters) if server_opt else None
    result = FederatedResult(strategy=strat.name, engine=engine)
    start_round = 0
    if resume_state is not None:
        start_round = resume_state.round_idx
        if resume_state.server_opt_state is not None:
            opt_state = resume_state.server_opt_state
        result.round_metrics = list(resume_state.round_metrics)

    backbone_dev = server.backbone
    if mesh is not None:
        # replicate the frozen backbone over the mesh once for the whole run;
        # the (changing) global adapters are re-placed at each round start
        _rep = NamedSharding(mesh, PartitionSpec())
        backbone_dev = jax.device_put(server.backbone, _rep)

    # chunk-resident client state (sharded engine): a chunk's stacked AdamW
    # state — and, in rounds that qualify for device-side stacked
    # aggregation, its adapters and Fisher diagonals too — never leaves the
    # devices between rounds. Last round's stacked outputs feed the next
    # round's dispatch (and the aggregation folds) directly, skipping the
    # per-round device→host gather and host→device restack. The matching
    # ``ClientState`` fields go stale while a cid has an entry in ``home``;
    # ``materialize`` writes the true rows back before anything reads them
    # (checkpoint snapshots, a reshuffled cohort, run end).
    resident: Dict[tuple, dict] = {}   # chunk key -> {k, opt, adp, fish}
    home: Dict[int, tuple] = {}        # cid -> chunk key holding its rows
    # client batch lists are immutable within a run, so a chunk's stacked +
    # mesh-placed (train, warm, fisher) batches are identical every round it
    # reappears — cache them keyed by the exact chunk membership
    batch_cache: Dict[tuple, tuple] = {}

    def materialize(cids_needed=None):
        keys = ({home[c] for c in cids_needed if c in home}
                if cids_needed is not None else set(home.values()))
        for ck in keys:
            ent = resident[ck]
            kk = ent["k"]
            opt_rows = client_lib._host_unstack(ent["opt"], kk)
            adp_rows = (client_lib._host_unstack(ent["adp"], kk)
                        if ent["adp"] is not None else None)
            fish_rows = (client_lib._host_unstack(ent["fish"], kk)
                         if ent["fish"] is not None else None)
            for j, c in enumerate(ck):
                if home.get(c) != ck:
                    continue
                fields = {"opt_state": opt_rows[j]}
                if adp_rows is not None:
                    fields["adapters"] = adp_rows[j]
                if fish_rows is not None:
                    fields["fisher"] = fish_rows[j]
                clients[index_of[c]] = dataclasses.replace(
                    clients[index_of[c]], **fields)
                del home[c]

    for r in range(start_round, rounds):
        cohort = list(sampler.select(r, cids))
        gbytes = tree_bytes(server.global_adapters)
        down_bytes = 0
        wire_up = 0
        n_dropped = n_crashed = 0
        # failure injection: dropped clients never start (no bytes, no
        # compute); crashed clients pull the global (bytes charged), then
        # die mid-update — local progress lost, state untouched, no upload
        if failures is not None and failures.active:
            alive = []
            for cid in cohort:
                if failures.drops(cid, r):
                    n_dropped += 1
                else:
                    alive.append(cid)
            cohort = []
            for cid in alive:
                if failures.crashes(cid, r):
                    st = clients[index_of[cid]]
                    if strat.downloads_global(st.rounds_participated):
                        down_bytes += gbytes
                    n_crashed += 1
                else:
                    cohort.append(cid)
        losses: List[float] = []           # cohort order
        updates: List[tuple] = []          # (theta, fisher, size), cohort order
        stream_acc = strat.agg_stream_init() if streaming else None
        stream_buf: List[tuple] = []
        stream_bytes = {"param_up": 0, "fisher_up": 0}
        folded_any = False
        # device-side stacked aggregation (sharded engine fast path): chunk
        # outputs fold into the merge where they live, padding rows masked
        # with zero weight — no per-client upload tree ever exists. Folds
        # are deferred to one fused dispatch at round end (the stacks stay
        # device-resident regardless, so deferral costs no extra memory).
        fast_acc = None
        fast_pend: List[tuple] = []    # (theta_stack, fisher_stack, weights)
        fast_losses: List[tuple] = []  # (chunk, device losses, real k)
        fast_bytes = {"param_up": 0, "fisher_up": 0}

        def apply_transforms(cid: int, theta):
            ctx = TransformCtx(cid=cid, round_idx=r)
            theta_wire = None
            for j, t in enumerate(transforms):
                theta, tstates[cid][j], w = t.apply(
                    ctx, theta, server.global_adapters, tstates[cid][j]
                )
                if w is not None:
                    theta_wire = w
            return theta, (theta_wire if theta_wire is not None else tree_bytes(theta))

        def offer(cid: int, state: ClientState, loss_mean: float):
            nonlocal wire_up, folded_any
            theta = strat.post_local_update(state, server.global_adapters, r)
            theta, wbytes = apply_transforms(cid, theta)
            wire_up += wbytes
            losses.append(loss_mean)
            if streaming:
                stream_buf.append((theta, state.fisher, state.n_examples))
                if len(stream_buf) >= agg_chunk:
                    fold_stream()
            else:
                updates.append((theta, state.fisher, state.n_examples))

        def fold_stream():
            nonlocal stream_acc, folded_any
            if not stream_buf:
                return
            ts = [u[0] for u in stream_buf]
            fs = [u[1] for u in stream_buf]
            ws = [u[2] for u in stream_buf]
            stream_bytes["param_up"] += sum(tree_bytes(t) for t in ts)
            stream_bytes["fisher_up"] += sum(
                tree_bytes(f) for f in fs if f is not None)
            stream_acc = strat.agg_stream_fold(
                stream_acc, ts, fs, ws, use_pallas=use_pallas)
            folded_any = True
            stream_buf.clear()

        if engine == "sequential":
            for cid in cohort:
                i = index_of[cid]
                if strat.downloads_global(clients[i].rounds_participated):
                    down_bytes += gbytes
                clients[i], metrics = client_lib.local_update(
                    cfg, server.backbone, clients[i], train_data[cid], hp,
                    strat, server.global_adapters, round_idx=r,
                )
                offer(cid, clients[i], metrics["loss_mean"])
        else:  # engine "vmap"/"sharded": group cohort by flags, then batch
            groups: Dict[tuple, List[int]] = {}
            for cid in cohort:
                st = clients[index_of[cid]]
                p = st.rounds_participated
                flags = (
                    strat.downloads_global(p),
                    st.local_adapters is not None and strat.local_warmup(p, hp),
                )
                groups.setdefault(flags, []).append(cid)

            global_dev = server.global_adapters
            if mesh is not None:
                global_dev = jax.device_put(
                    server.global_adapters, NamedSharding(mesh, PartitionSpec()))

            # dispatch plan: (downloads, chunk) across all flag-groups. The
            # dispatch width never changes aggregation numerics (offers are
            # replayed per client, in plan order, and streamed folds trigger
            # at agg_chunk boundaries only), so the sharded engine is free
            # to split groups into pipeline-sized, mesh-aligned chunks.
            plan: List[tuple] = []
            for (downloads, _), gcids in groups.items():
                if mesh is None:
                    width = agg_chunk if agg_chunk else len(gcids)
                else:
                    from repro.sharding import pad_to_multiple

                    width = (agg_chunk if agg_chunk
                             else min(_CHUNK_WIDTH_CAP,
                                      max(1, -(-len(gcids) // _PIPELINE_CHUNKS))))
                    width = pad_to_multiple(width, mesh.size)
                for chunk in _chunks(gcids, width):
                    plan.append((downloads, chunk))

            # device-side aggregation applies when every upload is the raw
            # adapter tree (stock post_local_update, no wire transforms, no
            # dual-adapter rows) and every chunk re-downloads the global —
            # then the stacked outputs ARE the uploads, and the fold can run
            # on the mesh with pad rows zero-weighted. Anything fancier
            # falls back to the per-client offer path below.
            fast_agg = (
                mesh is not None and strat.aggregates and not use_pallas
                and not transforms
                and type(strat).post_local_update is Strategy.post_local_update
                and all(flags[0] for flags in groups)
                and not any(
                    clients[index_of[gcids[0]]].local_adapters is not None
                    for gcids in groups.values())
            )

            # non-streaming aggregation must see cohort order; buffer per-cid
            pending: Dict[int, tuple] = {}
            # two-deep double buffer (sharded + overlap): while cohort k
            # computes on the devices, the host stacks and launches k+1 —
            # collect_cohort's device_get is the only blocking point, and it
            # always trails the most recent launch by one chunk
            depth = 2 if (mesh is not None and overlap) else 1
            inflight: deque = deque()

            def collect_one():
                nonlocal down_bytes, wire_up
                downloads, chunk, launched = inflight.popleft()
                kc = len(chunk)
                if fast_agg:
                    # nothing leaves the devices here: adapters/opt/fisher
                    # queue for the round-end fused stacked merge, losses
                    # for one round-end batched gather
                    new_states, loss_dev = client_lib.collect_cohort_deferred(
                        launched)
                    outs = launched.outs
                    wants_f = launched.prepared.wants_fisher is not None
                    ck = tuple(chunk)
                    resident[ck] = {"k": kc, "opt": outs[1], "adp": outs[0],
                                    "fish": outs[4] if wants_f else None}
                    for c in chunk:
                        home[c] = ck
                    width = jax.tree_util.tree_leaves(outs[0])[0].shape[0]
                    weights = [float(clients[index_of[c]].n_examples)
                               for c in chunk] + [0.0] * (width - kc)
                    fast_pend.append(
                        (outs[0], outs[4] if wants_f else None, weights))
                    row_pb = tree_bytes(outs[0]) // width
                    fast_bytes["param_up"] += row_pb * kc
                    wire_up += row_pb * kc
                    if wants_f:
                        fast_bytes["fisher_up"] += (
                            tree_bytes(outs[4]) // width) * kc
                elif mesh is not None:
                    # keep the new opt tree on the devices; per-client
                    # opt_state goes stale until materialize
                    new_states, mets = client_lib.collect_cohort(
                        launched, with_opt=False)
                    ck = tuple(chunk)
                    resident[ck] = {"k": kc, "opt": launched.outs[1],
                                    "adp": None, "fish": None}
                    for c in chunk:
                        home[c] = ck
                else:
                    new_states, mets = client_lib.collect_cohort(launched)
                if downloads:
                    down_bytes += gbytes * kc
                if fast_agg:
                    for c, ns in zip(chunk, new_states):
                        clients[index_of[c]] = ns
                    fast_losses.append((chunk, loss_dev, kc))
                    return
                for c, ns, m in zip(chunk, new_states, mets):
                    clients[index_of[c]] = ns
                    pending[c] = m["loss_mean"]
                    offer(c, ns, m["loss_mean"])

            for downloads, chunk in plan:
                opt0 = bx = None
                if mesh is not None:
                    ck = tuple(chunk)
                    bx = batch_cache.get(ck)
                    if (all(home.get(c) == ck for c in chunk)
                            and (downloads or resident[ck]["adp"] is None)):
                        opt0 = resident[ck]["opt"]
                    else:
                        # cohort reshuffled (or stale adapters would be
                        # stacked): pull resident rows back to their
                        # ClientStates before stacking from the host
                        needs = [c for c in chunk if c in home]
                        if needs:
                            materialize(needs)
                idxs = [index_of[c] for c in chunk]
                prepared = client_lib.prepare_cohort(
                    cfg, [clients[i] for i in idxs],
                    [train_data[c] for c in chunk], hp, strat, mesh=mesh,
                    opt0_override=opt0, batches_override=bx)
                if mesh is not None and bx is None:
                    batch_cache[ck] = prepared.args[4:7]
                inflight.append((downloads, chunk, client_lib.launch_cohort(
                    prepared, backbone_dev, global_dev)))
                if len(inflight) >= depth:
                    collect_one()
            while inflight:
                collect_one()
            # drop resident chunks no cid points at anymore (reshuffles),
            # and cached batch stacks for chunk keys this round didn't use
            if resident:
                live = set(home.values())
                for ck in [k for k in resident if k not in live]:
                    del resident[ck]
            if batch_cache:
                used = {tuple(chunk) for _, chunk in plan}
                for ck in [k for k in batch_cache if k not in used]:
                    del batch_cache[ck]
            if fast_losses:
                all_mets = client_lib.loss_metrics_deferred(
                    [l for _, l, _ in fast_losses],
                    [kk for _, _, kk in fast_losses])
                for (chunk, _, _), mets in zip(fast_losses, all_mets):
                    for c, m in zip(chunk, mets):
                        pending[c] = m["loss_mean"]
            # keep round metrics in cohort order regardless of grouping
            losses = [pending[c] for c in cohort if c in pending]

        if fast_pend:
            fast_acc = strat.agg_stream_fold_stacked(
                None, [p[0] for p in fast_pend],
                [p[1] for p in fast_pend], [p[2] for p in fast_pend],
                use_pallas=use_pallas)
        if fast_acc is not None:
            # device-side stacked merge: finalize where the folds ran, then
            # commit with byte totals identical to the per-client path
            # (k identical rows ⇒ k·row_bytes)
            prev_global = server.global_adapters
            merged = strat.agg_stream_finalize(fast_acc, use_pallas=use_pallas)
            server = server_lib.server_commit(
                server, merged,
                param_up=fast_bytes["param_up"],
                fisher_up=fast_bytes["fisher_up"],
                param_down=down_bytes, wire_up=wire_up,
            )
            if server_opt is not None:
                new_global, opt_state = server_opt.apply(
                    opt_state, prev_global, server.global_adapters
                )
                server = dataclasses.replace(server, global_adapters=new_global)
        elif strat.aggregates and (updates or stream_buf or folded_any):
            prev_global = server.global_adapters
            if streaming:
                fold_stream()
                merged = strat.agg_stream_finalize(stream_acc, use_pallas=use_pallas)
                server = server_lib.server_commit(
                    server, merged,
                    param_up=stream_bytes["param_up"],
                    fisher_up=stream_bytes["fisher_up"],
                    param_down=down_bytes, wire_up=wire_up,
                )
            else:
                thetas = [u[0] for u in updates]
                fishers = [u[1] for u in updates]
                sizes = [u[2] for u in updates]
                server = server_lib.server_aggregate(
                    server, strat, thetas, fishers, sizes,
                    use_pallas=use_pallas, wire_up=wire_up,
                    down_bytes=down_bytes,
                )
            if server_opt is not None:
                new_global, opt_state = server_opt.apply(
                    opt_state, prev_global, server.global_adapters
                )
                server = dataclasses.replace(server, global_adapters=new_global)
        elif down_bytes:
            # no merge this round (e.g. LocFT, or every starter crashed) but
            # clients still pulled the global at round start — that
            # broadcast crossed the wire
            server_lib.log_downloads(server, r, down_bytes)

        n = len(losses)
        # an empty cohort must be distinguishable from a perfect round:
        # participants==0 carries mean_loss=None, never a fake 0.0
        rm = {"round": r,
              "mean_loss": (sum(losses) / n) if n else None,
              "participants": n}
        if failures is not None:
            rm["dropped"] = n_dropped
            rm["crashed"] = n_crashed
        result.round_metrics.append(rm)
        if verbose:
            shown = "skipped (no participants)" if n == 0 else f"mean local loss {rm['mean_loss']:.4f}"
            print(f"  [{strat.name}] round {r}: {shown}")

        if ckpt is not None:
            if home and ckpt.would_save(r + 1):
                materialize()  # snapshots need true per-client state rows
            ckpt.maybe_save(r + 1, server=server, clients=clients,
                            tstates=tstates, opt_state=opt_state,
                            metrics=result.round_metrics)

    if home:
        materialize()
    if ckpt is not None:
        ckpt.final_save(rounds, server=server, clients=clients,
                        tstates=tstates, opt_state=opt_state,
                        metrics=result.round_metrics)
    result.server_opt_state = opt_state
    return result, server


def _run_buffered(
    cfg, server, strat, clients, cids, index_of, train_data, hp,
    transforms, tstates, server_opt, *, rounds, buffer_size, staleness_power,
    latency_fn, use_pallas, verbose, failures=None, ckpt=None,
    resume_state=None,
):
    """FedBuff-style async engine: merge every ``buffer_size`` completions.

    Simulated time advances in integer server ticks; ``latency_fn(cid,
    version)`` says how many ticks a client's local run takes (default 1 —
    homogeneous clients degenerate to synchronized rounds). A client always
    trains against the global *version it last downloaded*; its upload is
    merged with weight n_k/(1+τ)^p where τ is the number of server merges
    that happened while it was running. ``rounds`` counts server merges.

    Failure semantics (per *dispatch attempt*, keyed by the simulated tick):
    a dropped client never downloads and retries next tick; a crashed
    client downloads (bytes charged), trains for its latency, then its
    upload is lost and it re-dispatches. Stragglers add
    ``straggler_ticks`` to their completion time, so their uploads arrive
    stale and take the staleness discount.

    Checkpoints are taken at tick boundaries once ``checkpoint_every``
    merges have accumulated: the snapshot carries the event heap, live
    version snapshots with refcounts, and the partially-filled merge
    buffer, so a resumed run pops the identical completion order the
    uninterrupted run would have.
    """
    if not strat.aggregates:
        raise ValueError(
            f"engine='buffered' needs an aggregating strategy; {strat.name!r} "
            "never merges (local-only)")
    bsize = buffer_size if buffer_size else max(1, len(cids) // 2)
    bsize = min(bsize, len(cids))
    if latency_fn is None:
        latency_fn = lambda cid, version: 1  # noqa: E731
    opt_state = server_opt.init(server.global_adapters) if server_opt else None
    result = FederatedResult(strategy=strat.name, engine="buffered")
    gbytes = tree_bytes(server.global_adapters)

    # version -> [global snapshot, in-flight refcount]; clients in flight pin
    # the snapshot they downloaded, so memory is O(distinct live versions)
    version = 0
    snapshots: Dict[int, list] = {version: [server.global_adapters, 0]}
    events: List[tuple] = []  # (finish_tick, cid, version_started, kind)
    merges = 0
    # per-merge accumulators; the failure counters ride in the same dict so
    # checkpoints carry them and resume-replay reports identical metrics
    acc_up = {"param_up": 0, "fisher_up": 0, "wire_up": 0, "down": 0,
              "dropped": 0, "crashed": 0, "straggled": 0}
    buffer: List[tuple] = []  # (theta, fisher, size, loss_mean, staleness)

    def dispatch(cid: int, now: int):
        if failures is not None and failures.drops(cid, now):
            # offline this tick: no download, no snapshot pin, nothing ever
            # enqueued for upload; retry next tick
            acc_up["dropped"] += 1
            heapq.heappush(events, (now + 1, cid, version, _EV_RETRY))
            return
        st = clients[index_of[cid]]
        if strat.downloads_global(st.rounds_participated):
            acc_up["down"] += gbytes
        lat = max(1, int(latency_fn(cid, version)))
        if failures is not None and failures.straggles(cid, now):
            # slow attempt: completes, but ``straggler_ticks`` later — by
            # then the server has merged more versions, so this upload lands
            # with extra staleness and takes the n/(1+τ)^p discount
            acc_up["straggled"] += 1
            lat += failures.straggler_ticks
        if failures is not None and failures.crashes(cid, now):
            # downloaded, then died mid-update: the broadcast crossed the
            # wire but nothing comes back and no snapshot stays pinned
            acc_up["crashed"] += 1
            heapq.heappush(events, (now + lat, cid, version, _EV_RETRY))
            return
        snapshots[version][1] += 1
        heapq.heappush(events, (now + lat, cid, version, _EV_RUN))

    if resume_state is not None:
        b = resume_state.buffered
        if b is None:
            raise CheckpointError(
                "checkpoint has no buffered-engine state; it was written by "
                "a synchronized engine")
        version = b.version
        snapshots = dict(b.snapshots)
        # the current version's snapshot IS the restored global (saved once)
        snapshots.setdefault(version, [server.global_adapters, 0])
        events = list(b.events)  # a valid heap, restored verbatim
        buffer = list(b.buffer)
        acc_up = dict(b.acc_up)
        for k in ("dropped", "crashed", "straggled"):
            acc_up.setdefault(k, 0)  # pre-failure-counter checkpoints
        merges = resume_state.round_idx
        if resume_state.server_opt_state is not None:
            opt_state = resume_state.server_opt_state
        result.round_metrics = list(resume_state.round_metrics)
    else:
        for cid in cids:
            dispatch(cid, 0)

    while merges < rounds:
        if ckpt is not None:
            ckpt.maybe_save(
                merges, server=server, clients=clients, tstates=tstates,
                opt_state=opt_state, metrics=result.round_metrics,
                buffered=BufferedState(
                    version=version, events=list(events),
                    snapshots=snapshots, buffer=buffer, acc_up=acc_up))
        # drain every completion in this simulated tick before re-dispatching
        # any of them: a client re-downloads only after its upload is acked,
        # by which point the server has folded everything this tick produced
        # (so uniform latency degenerates to synchronized zero-staleness
        # rounds instead of racing re-downloads against the merge)
        now = events[0][0]
        done_this_tick: List[int] = []
        while events and events[0][0] == now and merges < rounds:
            _, cid, v_start, kind = heapq.heappop(events)
            done_this_tick.append(cid)
            if kind != _EV_RUN:
                continue  # failed attempt coming back for re-dispatch
            snap_global, _ = snapshots[v_start]
            i = index_of[cid]
            clients[i], metrics = client_lib.local_update(
                cfg, server.backbone, clients[i], train_data[cid], hp, strat,
                snap_global, round_idx=merges,
            )
            theta = strat.post_local_update(clients[i], snap_global, merges)
            ctx = TransformCtx(cid=cid, round_idx=merges)
            theta_wire = None
            for j, t in enumerate(transforms):
                theta, tstates[cid][j], w = t.apply(ctx, theta, snap_global,
                                                    tstates[cid][j])
                if w is not None:
                    theta_wire = w
            acc_up["wire_up"] += theta_wire if theta_wire is not None else tree_bytes(theta)
            acc_up["param_up"] += tree_bytes(theta)
            if clients[i].fisher is not None:
                acc_up["fisher_up"] += tree_bytes(clients[i].fisher)
            buffer.append((theta, clients[i].fisher, clients[i].n_examples,
                           metrics["loss_mean"], version - v_start))
            snapshots[v_start][1] -= 1
            if snapshots[v_start][1] == 0 and v_start != version:
                del snapshots[v_start]

            if len(buffer) >= bsize:
                weights = [n / (1.0 + tau) ** staleness_power
                           for _, _, n, _, tau in buffer]
                sacc = strat.agg_stream_init()
                sacc = strat.agg_stream_fold(
                    sacc, [b[0] for b in buffer], [b[1] for b in buffer], weights,
                    use_pallas=use_pallas)
                merged = strat.agg_stream_finalize(sacc, use_pallas=use_pallas)
                prev_global = server.global_adapters
                server = server_lib.server_commit(
                    server, merged,
                    param_up=acc_up["param_up"], fisher_up=acc_up["fisher_up"],
                    param_down=acc_up["down"], wire_up=acc_up["wire_up"],
                )
                if server_opt is not None:
                    new_global, opt_state = server_opt.apply(
                        opt_state, prev_global, server.global_adapters)
                    server = dataclasses.replace(server, global_adapters=new_global)
                blosses = [b[3] for b in buffer]
                bstale = [b[4] for b in buffer]
                rm = {"round": merges,
                      "mean_loss": sum(blosses) / len(blosses),
                      "participants": len(buffer),
                      "mean_staleness": sum(bstale) / len(bstale)}
                if failures is not None:
                    # failed/slow dispatch attempts since the last merge
                    rm["dropped"] = acc_up["dropped"]
                    rm["crashed"] = acc_up["crashed"]
                    rm["straggled"] = acc_up["straggled"]
                result.round_metrics.append(rm)
                if verbose:
                    print(f"  [{strat.name}] merge {merges}: mean loss "
                          f"{rm['mean_loss']:.4f} staleness {rm['mean_staleness']:.2f}")
                merges += 1
                version += 1
                snapshots[version] = [server.global_adapters, 0]
                buffer.clear()
                acc_up = {"param_up": 0, "fisher_up": 0, "wire_up": 0, "down": 0,
                          "dropped": 0, "crashed": 0, "straggled": 0}

        for cid in done_this_tick:
            dispatch(cid, now)

    if ckpt is not None:
        # the exit-state snapshot lets a later run extend this one with more
        # merges (resume + larger ``rounds``); note that stopping at exactly
        # ``rounds`` merges leaves same-tick completions undrained, so an
        # extended run is a continuation of THIS schedule, not a replay of a
        # longer uninterrupted one — mid-run snapshots (checkpoint_every)
        # are the replay-equivalent ones
        ckpt.final_save(
            merges, server=server, clients=clients, tstates=tstates,
            opt_state=opt_state, metrics=result.round_metrics,
            buffered=BufferedState(
                version=version, events=list(events), snapshots=snapshots,
                buffer=buffer, acc_up=acc_up))
    result.server_opt_state = opt_state
    return result, server


def run_centralized(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    steps: int = 100,
    hp: HyperParams = HyperParams(),
    verbose: bool = False,
) -> FederatedResult:
    """Upper bound: one 'client' holding the union of all data."""
    all_train: List[Batch] = []
    for cid in sorted(train_data):
        all_train.extend(train_data[cid])
    k_server, k_client = jax.random.split(key)
    server = server_lib.init_server(k_server, cfg)
    state = client_lib.init_client(
        k_client, cfg, cid=0, n_examples=len(all_train), strategy="fedavg"
    )
    hp_c = HyperParams(
        lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        local_steps=steps, prox_mu=hp.prox_mu, fisher_batches=hp.fisher_batches,
    )
    state, metrics = client_lib.local_update(
        cfg, server.backbone, state, all_train, hp_c, "fedavg",
        server.global_adapters, round_idx=0,
    )
    result = FederatedResult(strategy="centralized")
    result.round_metrics.append(
        {"round": 0, "mean_loss": metrics["loss_mean"], "participants": 1}
    )
    # the centralized upper bound still moves bytes: one initial broadcast
    # down to the lone trainer, one adapter upload back — log it so comm
    # tables comparing against this bound don't silently read zeros
    server.comm.log_round(RoundTraffic(
        round_idx=0,
        param_up=tree_bytes(state.adapters),
        param_down=tree_bytes(server.global_adapters),
        param_up_wire=tree_bytes(state.adapters),
    ))
    for cid in sorted(eval_data):
        acc = client_lib.eval_client(cfg, server.backbone, state.adapters, None, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(result.client_accuracy)
    result.comm_totals = server.comm.totals()
    result.server = server
    result.clients = [state]
    if verbose:
        print(f"  [centralized] acc {result.avg_accuracy:.4f}")
    return result
