"""Federated orchestration — Alg. 1 of the paper as a strategy-agnostic engine.

``run_federated`` is a thin loop over the ``repro.strategies`` hooks:

    sampler.select          -> which clients run this round
    client.local_update     -> T local steps via the strategy's loss/fisher hooks
    strategy.post_local_update -> what each client offers for upload
    transforms[*].apply     -> DP / quantization / sparsification on the wire
    strategy.aggregate      -> merge (via server.server_aggregate, which logs comm)
    server_opt.apply        -> optional FedOpt step on the merged pseudo-gradient
    strategy.eval_params    -> which params each client evaluates at the end

Methods are plugins (``repro.strategies``): the engine never branches on a
strategy name. Strings like ``strategy="fednano"`` resolve through the
registry, so the legacy API keeps working. Clients execute sequentially in
this process (one CPU); on the production mesh the server step batches all
clients' activations across the ``data``/``pod`` axes (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.core import client as client_lib
from repro.core import server as server_lib
from repro.core.client import ClientState, HyperParams
from repro.core.types import Batch
from repro.strategies.base import Strategy, get_strategy
from repro.strategies.sampling import ClientSampler
from repro.strategies.server_opt import ServerOpt
from repro.strategies.transforms import (
    TransformCtx,
    UpdateTransform,
    default_transforms,
)
from repro.utils import tree_bytes


@dataclass
class FederatedResult:
    strategy: str
    round_metrics: List[Dict] = field(default_factory=list)
    client_accuracy: Dict[int, float] = field(default_factory=dict)
    avg_accuracy: float = 0.0
    comm_totals: Dict[str, int] = field(default_factory=dict)
    server: Optional[object] = None
    clients: Optional[List[ClientState]] = None


def run_federated(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    strategy: Union[str, Strategy] = "fednano",
    rounds: int = 10,
    hp: HyperParams = HyperParams(),
    use_pallas: bool = False,
    server: Optional[server_lib.ServerState] = None,
    verbose: bool = False,
    transforms: Optional[Sequence[UpdateTransform]] = None,
    server_opt: Optional[ServerOpt] = None,
    sampler: Optional[ClientSampler] = None,
) -> FederatedResult:
    """Run R rounds of federated NanoAdapter tuning.

    ``transforms`` defaults to the ``hp``-driven chain (DP, then int8+EF);
    ``server_opt`` defaults to the strategy's own (usually None = identity);
    ``sampler`` defaults to full participation.
    """
    strat = get_strategy(strategy)
    if transforms is None:
        transforms = default_transforms(hp)
    if server_opt is None:
        server_opt = strat.server_opt()
    if sampler is None:
        sampler = ClientSampler()

    k_server, k_clients = jax.random.split(key)
    if server is None:
        server = server_lib.init_server(k_server, cfg)
    cids = sorted(train_data)
    index_of = {cid: i for i, cid in enumerate(cids)}
    ckeys = jax.random.split(k_clients, len(cids))
    clients = [
        strat.init_client(ck, cfg, cid, n_examples=len(train_data[cid]))
        for ck, cid in zip(ckeys, cids)
    ]
    tstates = {cid: [None] * len(transforms) for cid in cids}
    opt_state = server_opt.init(server.global_adapters) if server_opt else None

    result = FederatedResult(strategy=strat.name)
    for r in range(rounds):
        thetas, fishers, sizes, losses = [], [], [], []
        wire_up = 0
        for cid in sampler.select(r, cids):
            i = index_of[cid]
            clients[i], metrics = client_lib.local_update(
                cfg,
                server.backbone,
                clients[i],
                train_data[cid],
                hp,
                strat,
                server.global_adapters,
                round_idx=r,
            )
            theta = strat.post_local_update(clients[i], server.global_adapters, r)
            ctx = TransformCtx(cid=cid, round_idx=r)
            theta_wire = None
            for j, t in enumerate(transforms):
                theta, tstates[cid][j], w = t.apply(
                    ctx, theta, server.global_adapters, tstates[cid][j]
                )
                if w is not None:
                    theta_wire = w
            wire_up += theta_wire if theta_wire is not None else tree_bytes(theta)
            thetas.append(theta)
            fishers.append(clients[i].fisher)
            sizes.append(clients[i].n_examples)
            losses.append(metrics["loss_mean"])
        if strat.aggregates and thetas:  # a custom sampler may return no cohort
            prev_global = server.global_adapters
            server = server_lib.server_aggregate(
                server, strat, thetas, fishers, sizes,
                use_pallas=use_pallas, wire_up=wire_up,
            )
            if server_opt is not None:
                new_global, opt_state = server_opt.apply(
                    opt_state, prev_global, server.global_adapters
                )
                server = dataclasses.replace(server, global_adapters=new_global)
        rm = {"round": r, "mean_loss": sum(losses) / max(len(losses), 1),
              "participants": len(losses)}
        result.round_metrics.append(rm)
        if verbose:
            print(f"  [{strat.name}] round {r}: mean local loss {rm['mean_loss']:.4f}")

    # final evaluation: every client, on the params its strategy designates
    # (global adapters for most; LocFT/FedDPA-F evaluate personalized params).
    for cid in cids:
        adp, ladp = strat.eval_params(server.global_adapters, clients[index_of[cid]])
        acc = client_lib.eval_client(cfg, server.backbone, adp, ladp, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(cids)
    result.comm_totals = server.comm.totals()
    result.server = server
    result.clients = clients
    return result


def run_centralized(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    steps: int = 100,
    hp: HyperParams = HyperParams(),
    verbose: bool = False,
) -> FederatedResult:
    """Upper bound: one 'client' holding the union of all data."""
    all_train: List[Batch] = []
    for cid in sorted(train_data):
        all_train.extend(train_data[cid])
    k_server, k_client = jax.random.split(key)
    server = server_lib.init_server(k_server, cfg)
    state = client_lib.init_client(
        k_client, cfg, cid=0, n_examples=len(all_train), strategy="fedavg"
    )
    hp_c = HyperParams(
        lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        local_steps=steps, prox_mu=hp.prox_mu, fisher_batches=hp.fisher_batches,
    )
    state, metrics = client_lib.local_update(
        cfg, server.backbone, state, all_train, hp_c, "fedavg",
        server.global_adapters, round_idx=0,
    )
    result = FederatedResult(strategy="centralized")
    result.round_metrics.append({"round": 0, "mean_loss": metrics["loss_mean"]})
    for cid in sorted(eval_data):
        acc = client_lib.eval_client(cfg, server.backbone, state.adapters, None, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(result.client_accuracy)
    result.server = server
    result.clients = [state]
    if verbose:
        print(f"  [centralized] acc {result.avg_accuracy:.4f}")
    return result
