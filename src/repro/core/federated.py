"""Federated orchestration — Alg. 1 of the paper, end to end.

``run_federated`` drives R communication rounds over K clients for any
strategy in {fednano, fednano_ef, fedavg, fedprox, feddpa_f, locft}, plus a
``centralized`` upper-bound runner. Clients execute sequentially in this
process (one CPU); on the production mesh the server step batches all
clients' activations across the ``data``/``pod`` axes (DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro.core import client as client_lib
from repro.core import server as server_lib
from repro.core.client import ClientState, HyperParams
from repro.core.types import Batch


@dataclass
class FederatedResult:
    strategy: str
    round_metrics: List[Dict] = field(default_factory=list)
    client_accuracy: Dict[int, float] = field(default_factory=dict)
    avg_accuracy: float = 0.0
    comm_totals: Dict[str, int] = field(default_factory=dict)
    server: Optional[object] = None
    clients: Optional[List[ClientState]] = None


def run_federated(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    strategy: str = "fednano",
    rounds: int = 10,
    hp: HyperParams = HyperParams(),
    use_pallas: bool = False,
    server: Optional[server_lib.ServerState] = None,
    verbose: bool = False,
) -> FederatedResult:
    """Run R rounds of federated NanoAdapter tuning."""
    k_server, k_clients = jax.random.split(key)
    if server is None:
        server = server_lib.init_server(k_server, cfg)
    cids = sorted(train_data)
    ckeys = jax.random.split(k_clients, len(cids))
    clients = [
        client_lib.init_client(ck, cfg, cid, n_examples=len(train_data[cid]), strategy=strategy)
        for ck, cid in zip(ckeys, cids)
    ]

    result = FederatedResult(strategy=strategy)
    wire_up_total = 0
    for r in range(rounds):
        thetas, fishers, sizes, losses = [], [], [], []
        for i, cid in enumerate(cids):
            clients[i], metrics = client_lib.local_update(
                cfg,
                server.backbone,
                clients[i],
                train_data[cid],
                hp,
                strategy,
                server.global_adapters,
                round_idx=r,
            )
            theta = clients[i].adapters
            # --- beyond-paper upload path: DP then int8+error-feedback ---
            if hp.dp_clip > 0.0:
                from repro.core.privacy import privatize_update

                dpk = jax.random.fold_in(jax.random.PRNGKey(1234 + cid), r)
                theta, _ = privatize_update(
                    dpk, theta, server.global_adapters,
                    clip_norm=hp.dp_clip, noise_mult=hp.dp_noise,
                )
            if hp.compress_uploads:
                from repro.core.compression import (
                    compress_update,
                    init_error_feedback,
                )
                from repro.utils import tree_add

                err = clients[i].comp_error or init_error_feedback(theta)
                q, err, recon = compress_update(theta, server.global_adapters, err)
                clients[i].comp_error = err
                theta = tree_add(server.global_adapters, recon)
                wire_up_total += q.wire_bytes
            thetas.append(theta)
            fishers.append(clients[i].fisher)
            sizes.append(clients[i].n_examples)
            losses.append(metrics["loss_mean"])
        if strategy != "locft":
            server = server_lib.server_aggregate(
                server, strategy, thetas, fishers, sizes, use_pallas=use_pallas
            )
        rm = {"round": r, "mean_loss": sum(losses) / len(losses)}
        result.round_metrics.append(rm)
        if verbose:
            print(f"  [{strategy}] round {r}: mean local loss {rm['mean_loss']:.4f}")

    # final evaluation: each client evaluates the GLOBAL adapters on its own
    # held-out split (LocFT/FedDPA-F evaluate their personalized params).
    for i, cid in enumerate(cids):
        if strategy == "locft":
            adp, ladp = clients[i].adapters, None
        elif strategy == "feddpa_f":
            adp, ladp = server.global_adapters, clients[i].local_adapters
        else:
            adp, ladp = server.global_adapters, None
        acc = client_lib.eval_client(cfg, server.backbone, adp, ladp, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(cids)
    result.comm_totals = server.comm.totals()
    if hp.compress_uploads:
        result.comm_totals["param_up_wire"] = wire_up_total
    result.server = server
    result.clients = clients
    return result


def run_centralized(
    key,
    cfg,
    train_data: Dict[int, List[Batch]],
    eval_data: Dict[int, List[Batch]],
    *,
    steps: int = 100,
    hp: HyperParams = HyperParams(),
    verbose: bool = False,
) -> FederatedResult:
    """Upper bound: one 'client' holding the union of all data."""
    all_train: List[Batch] = []
    for cid in sorted(train_data):
        all_train.extend(train_data[cid])
    server = server_lib.init_server(key, cfg)
    state = client_lib.init_client(key, cfg, cid=0, n_examples=len(all_train), strategy="fedavg")
    hp_c = HyperParams(
        lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        local_steps=steps, prox_mu=hp.prox_mu, fisher_batches=hp.fisher_batches,
    )
    state, metrics = client_lib.local_update(
        cfg, server.backbone, state, all_train, hp_c, "fedavg",
        server.global_adapters, round_idx=0,
    )
    result = FederatedResult(strategy="centralized")
    result.round_metrics.append({"round": 0, "mean_loss": metrics["loss_mean"]})
    for cid in sorted(eval_data):
        acc = client_lib.eval_client(cfg, server.backbone, state.adapters, None, eval_data[cid])
        result.client_accuracy[cid] = acc
    result.avg_accuracy = sum(result.client_accuracy.values()) / len(result.client_accuracy)
    if verbose:
        print(f"  [centralized] acc {result.avg_accuracy:.4f}")
    return result
