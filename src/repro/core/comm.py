"""Bytes-on-the-wire accounting (paper Tab. 1 reproduction).

Two traffic classes, both tracked per round:

  * **parameter plane** — what FL methods ship each round:
        FedNano:   NanoAdapter deltas up (+ diagonal FIM up), merged adapters down
        FedDPA-F:  full PEFT adapter set up/down (modeled analytically)
  * **activation plane** — FedNano's split execution ships adapted embeddings
        up and ∂loss/∂embeddings down *during local training*. Prior PEFT FL
        has zero activation traffic (the model is local) — the trade the
        paper makes implicitly; we surface it honestly.

``client_storage_params`` reproduces Tab. 1's "Client Params": everything a
client must persist (frozen encoder stub + connector + token embedder +
adapters) vs the full-model client footprint of PEFT-based FL.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List

from repro.utils import tree_bytes, tree_size


@dataclass
class RoundTraffic:
    round_idx: int
    param_up: int = 0        # bytes: adapters (+fisher) uploaded, summed over clients
    param_down: int = 0      # bytes: merged adapters broadcast
    fisher_up: int = 0       # bytes: diagonal FIM uploads (FedNano only)
    act_up: int = 0          # bytes: split activations client -> server
    act_down: int = 0        # bytes: gradient activations server -> client
    param_up_wire: int = 0   # bytes actually on the wire after upload
                             # transforms (== param_up when uncompressed)

    def to_dict(self) -> Dict[str, int]:
        """JSON-safe form (checkpoints persist the full per-round log so a
        resumed run's totals equal the uninterrupted run's, byte for byte)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, int]) -> "RoundTraffic":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"RoundTraffic checkpoint entry carries unknown fields "
                f"{sorted(unknown)}; the comm-log format has diverged")
        return cls(**d)


@dataclass
class CommLog:
    rounds: List[RoundTraffic] = field(default_factory=list)

    def log_round(self, r: RoundTraffic):
        self.rounds.append(r)

    def totals(self) -> Dict[str, int]:
        out = {"param_up": 0, "param_down": 0, "fisher_up": 0, "act_up": 0,
               "act_down": 0, "param_up_wire": 0}
        for r in self.rounds:
            for k in out:
                out[k] += getattr(r, k)
        return out

    def state_dict(self) -> List[Dict[str, int]]:
        return [r.to_dict() for r in self.rounds]

    @classmethod
    def from_state_dict(cls, rounds: List[Dict[str, int]]) -> "CommLog":
        return cls(rounds=[RoundTraffic.from_dict(d) for d in rounds])


def adapter_upload_params(cfg) -> int:
    """Trainable NanoAdapter parameters a client uploads per round."""
    return len(cfg.adapter.modalities) * 2 * cfg.d_model * cfg.adapter.rank


def backbone_param_count(cfg) -> int:
    """Analytic parameter count of the full backbone (no materialization)."""
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.qkv_bias:
        attn += cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd
    if cfg.act in ("swiglu", "geglu"):
        mlp = 3 * d * f
    else:
        mlp = 2 * d * f
    norms = 2 * d

    total = 0
    if cfg.family == "moe":
        m = cfg.moe
        experts = m.n_experts * 3 * d * f
        shared = 3 * d * m.shared_d_ff if m.shared_d_ff else 0
        router = d * m.n_experts
        total += L * (attn + experts + shared + router + norms)
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * d
        H = d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.d_state
        in_proj = d * (2 * d_inner + 2 * s.d_state + H)
        block = in_proj + s.d_conv * conv_dim + conv_dim + 3 * H + d_inner + d_inner * d
        total += L * (block + d)
    elif cfg.family == "hybrid":
        dr = cfg.rglru.d_rnn or d
        rec = 2 * d * dr + cfg.rglru.conv_width * dr + dr + 2 * (dr * dr + dr) + dr * d + dr
        n_attn = L // 3
        n_rec = L - n_attn
        total += n_rec * (rec + mlp + norms) + n_attn * (attn + mlp + norms)
    else:  # dense / vlm / audio decoder
        total += L * (attn + mlp + norms)
        if cfg.family == "audio":
            # encoder layers + cross attention in decoder
            total += cfg.n_enc_layers * (attn + mlp + norms)
            total += L * (attn + d)  # cross-attn + its norm
            total += cfg.max_seq_len * d + cfg.enc_seq_len * d  # learned positions

    total += v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d
    total += d  # final norm
    if cfg.frontend_dim:
        total += cfg.frontend_dim * d + d  # connector
    return total


def client_storage_params(cfg, *, encoder_params: int | None = None) -> Dict[str, int]:
    """Tab. 1 'Client Params' decomposition for FedNano vs PEFT-FL.

    encoder_params: size of the stubbed frontend tower (defaults: CLIP
    ViT-L/14-336 ≈ 303.5M for vlm, whisper conv ≈ 7.4M for audio, 0 for text).
    """
    if encoder_params is None:
        encoder_params = {"vlm": 303_500_000, "audio": 7_400_000}.get(cfg.family, 0)
    connector = cfg.frontend_dim * cfg.d_model + cfg.d_model if cfg.frontend_dim else 0
    embedder = cfg.vocab_size * cfg.d_model
    adapters = adapter_upload_params(cfg)
    backbone = backbone_param_count(cfg)
    return {
        "encoder": encoder_params,
        "connector": connector,
        "token_embedder": embedder,
        "adapters": adapters,
        "fednano_client_total": encoder_params + connector + adapters,
        "fednano_client_total_with_embedder": encoder_params + connector + embedder + adapters,
        "backbone_total": backbone,
        "peft_client_total": backbone + encoder_params + connector,
        "uploads_fednano": adapters,
        "uploads_peft_rank64": _peft_adapter_params(cfg, rank=64),
    }


def _peft_adapter_params(cfg, rank: int) -> int:
    """Rank-64 LoRA on every linear projection of every layer (FedDPA-style:
    q, k, v, o + the 3 MLP matrices) — reproduces the paper's 180.89M
    (2.50 %) upload figure for LLaVA-1.5-7B within ~2 %."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    attn = (
        rank * (d + cfg.n_heads * hd)            # q
        + 2 * rank * (d + cfg.n_kv_heads * hd)   # k, v
        + rank * (cfg.n_heads * hd + d)          # o
    )
    n_mlp = 3 if cfg.act in ("swiglu", "geglu") else 2
    mlp = n_mlp * rank * (d + cfg.d_ff)
    n_layers = cfg.n_layers + (cfg.n_enc_layers or 0)
    return n_layers * (attn + mlp)
