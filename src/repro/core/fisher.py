"""Diagonal Fisher information for NanoAdapter params (paper §3.4).

The FIM serves as the precision matrix of the Laplace approximation to the
client posterior. FedNano approximates the full FIM by its diagonal
(Kirkpatrick et al. 2017) computed from squared gradients (Wu et al. 2023):

    F ≈ E_{(v,q,a)~D_k} [ (∇_θ log p(a|v,q,θ))² ]

Two estimators (paper §4.4, Tab. 7):
  * dedicated pass (``fisher_pass``) — extra fwd+bwd per round on local data
    with the *final* local params: precise, the default FedNano.
  * streaming / "EF" (``FisherAccumulator`` fed during training) — reuses the
    squared grads of normal training steps: zero extra compute, slightly
    stale (averaged over the local trajectory). FedNano-EF.
"""
from __future__ import annotations

from typing import Callable, Iterable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import tree_zeros_like


class FisherAccumulator(NamedTuple):
    sum_sq: dict   # Σ grad² pytree (adapter structure)
    count: jax.Array  # number of accumulated gradient evaluations

    @staticmethod
    def init(adapters) -> "FisherAccumulator":
        return FisherAccumulator(
            sum_sq=tree_zeros_like(adapters), count=jnp.zeros((), jnp.float32)
        )

    def update(self, grads) -> "FisherAccumulator":
        new = jax.tree.map(lambda s, g: s + jnp.square(g.astype(s.dtype)), self.sum_sq, grads)
        return FisherAccumulator(sum_sq=new, count=self.count + 1.0)

    def finalize(self, eps: float = 1e-8):
        """Mean squared gradient (diagonal FIM estimate)."""
        c = jnp.maximum(self.count, 1.0)
        return jax.tree.map(lambda s: s / c + eps, self.sum_sq)


def fisher_pass(
    grad_fn: Callable, adapters, batches: Iterable, *, eps: float = 1e-8
):
    """Dedicated FIM pass: Σ over batches of grad(loss)² at fixed params.

    grad_fn(adapters, batch) -> grads pytree (same structure as adapters).
    """
    acc = FisherAccumulator.init(adapters)
    for batch in batches:
        grads = grad_fn(adapters, batch)
        acc = acc.update(grads)
    return acc.finalize(eps=eps)


def fisher_size_bytes(fisher) -> int:
    from repro.utils import tree_bytes

    return tree_bytes(fisher)
