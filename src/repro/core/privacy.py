"""Client-level differential privacy for NanoAdapter updates (DP-FedAvg style).

Addresses the paper's privacy future-work ("incorporating advanced
privacy-preserving techniques such as differential privacy … without
sacrificing the computational and communication efficiency").

Mechanism (McMahan et al. 2018, client-level DP): before upload, the
adapter DELTA is clipped to L2 norm ≤ C and isotropic Gaussian noise
σ·C·N(0, I) is added. Because FedNano uploads are 0.01 % of the model, the
noise dimensionality — and thus the accuracy cost at fixed ε — is orders of
magnitude below full-model or PEFT-in-LLM FL: tiny uploads are not just a
bandwidth win but a *privacy-utility* win (the extension's thesis).

``privatize_update`` returns the noised delta plus the accounting tuple
(clip norm, σ) for an external moments accountant; ``dp_sigma`` gives the
per-round σ for a (ε, δ) target via the simple Gaussian-mechanism bound
(composition across rounds left to the caller's accountant).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.utils import tree_sq_norm, tree_sub


def clip_by_global_norm(tree, max_norm: float):
    norm = jnp.sqrt(tree_sq_norm(tree))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def add_gaussian_noise(key, tree, stddev: float):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    noised = [
        x + stddev * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
        for x, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, noised)


def privatize_update(
    key, adapters: Dict, global_ref: Dict, *, clip_norm: float, noise_mult: float
) -> Tuple[Dict, Dict]:
    """Returns (privatized θ_k suitable for aggregation, accounting info)."""
    delta = tree_sub(adapters, global_ref)
    delta, pre_norm = clip_by_global_norm(delta, clip_norm)
    if noise_mult > 0:
        delta = add_gaussian_noise(key, delta, noise_mult * clip_norm)
    theta = jax.tree.map(jnp.add, global_ref, delta)
    return theta, {"pre_clip_norm": pre_norm, "sigma": noise_mult * clip_norm}


def dp_sigma(epsilon: float, delta: float) -> float:
    """Single-release Gaussian-mechanism noise multiplier for (ε, δ)."""
    if epsilon <= 0:
        raise ValueError("epsilon must be > 0")
    return math.sqrt(2.0 * math.log(1.25 / delta)) / epsilon
