"""Client-side local tuning (Alg. 1, ClientUpdate).

Each client trains ONLY its NanoAdapters (optionally a dual local adapter for
the FedDPA-F baseline). The backbone is a frozen constant — gradients are
taken w.r.t. the adapter pytree alone, so the server-hosted LLM weights are
never perturbed and nothing model-sized is ever shipped.

Strategy-specific behaviour is injected through the ``repro.strategies``
hooks (``wrap_local_loss``, ``wants_fisher``, ``downloads_global``,
``local_warmup``); this module only knows how to run T adamw steps over a
wrapped objective and estimate the diagonal FIM. ``strategy`` arguments
accept either a registered name ("fednano", "fedprox", …) or a ``Strategy``
instance — names are resolved through the registry.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import adapters as adapters_lib
from repro.core.fisher import FisherAccumulator, fisher_pass
from repro.core.types import Batch
from repro.optim import adamw_init, adamw_update


@dataclass(frozen=True)
class HyperParams:
    lr: float = 1e-3
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    local_steps: int = 10          # T local steps per round (paper: 1 epoch)
    prox_mu: float = 0.01          # FedProx proximal coefficient
    fisher_batches: int = 4        # batches for the dedicated FIM pass
    dpa_warmup_rounds: int = 1     # FedDPA-F: rounds that train the local adapter
    # --- beyond-paper extensions (repro.core.{compression,privacy}) ---
    compress_uploads: bool = False # int8 delta quantization + error feedback
    dp_clip: float = 0.0           # client-level DP: L2 clip of the delta (0 = off)
    dp_noise: float = 0.0          # client-level DP: Gaussian noise multiplier


@dataclass
class ClientState:
    cid: int
    adapters: Dict            # global/shared NanoAdapters (uploaded)
    opt_state: Any
    n_examples: int
    local_adapters: Optional[Dict] = None   # FedDPA-F personal adapter
    fisher: Optional[Dict] = None           # last computed diagonal FIM
    rounds_participated: int = 0            # local_update calls so far (drives
                                            # download/warmup under sampling)


def init_client(key, cfg, cid: int, n_examples: int, strategy) -> ClientState:
    """Build a client via the strategy's ``init_client`` hook."""
    from repro.strategies.base import get_strategy

    return get_strategy(strategy).init_client(key, cfg, cid, n_examples)


def _combined_loss(cfg, backbone, adapters, local_adapters, batch):
    """FedDPA composition: shared adapter then personal adapter."""
    if local_adapters is None:
        return adapters_lib.fednano_loss(cfg, backbone, adapters, batch)
    # compose: run NanoEdge with the shared adapters, then apply the personal
    # adapters on the resulting embeddings (dual-adapter design).
    embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
        cfg, backbone, adapters, batch
    )
    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=cfg.use_pallas)
    if "text" in local_adapters:
        embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
    if enc is not None and "image" in local_adapters:
        enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
    from repro.models import model as model_lib

    loss, aux = model_lib.loss_fn(cfg, backbone, embeds, positions, labels, mask, enc)
    return loss, aux


@functools.lru_cache(maxsize=64)
def make_train_step(cfg, strategy, hp: HyperParams) -> Callable:
    """Jitted local train step, shared across clients (compiled once per
    (cfg, strategy, hp) — strategies are frozen dataclasses, so value-equal
    instances hit the same cache entry)."""

    def step(backbone, adapters, local_adapters, opt_state, batch, global_ref, ef_sum, ef_cnt):
        def base_loss(adp):
            return _combined_loss(cfg, backbone, adp, local_adapters, batch)

        loss_fn = strategy.wrap_local_loss(base_loss, hp, global_ref)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(adapters)
        new_adapters, new_opt = adamw_update(
            grads, opt_state, adapters,
            lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        )
        # streaming (EF) Fisher accumulation — free squared grads
        new_ef_sum = jax.tree.map(
            lambda s, g: s + jnp.square(g.astype(s.dtype)), ef_sum, grads
        )
        return new_adapters, new_opt, loss, new_ef_sum, ef_cnt + 1.0

    return jax.jit(step)


@functools.lru_cache(maxsize=64)
def make_fisher_grad(cfg) -> Callable:
    """grad of the plain task loss (no prox) — used by the dedicated FIM pass."""

    def gfn(backbone, adapters, batch):
        def loss_fn(adp):
            loss, _ = adapters_lib.fednano_loss(cfg, backbone, adp, batch)
            return loss

        return jax.grad(loss_fn)(adapters)

    return jax.jit(gfn)


@functools.lru_cache(maxsize=64)
def make_local_adapter_step(cfg, hp: HyperParams) -> Callable:
    """FedDPA-F warmup: train the PERSONAL adapter (shared adapter frozen)."""

    def step(backbone, adapters, local_adapters, opt_state, batch):
        def loss_fn(ladp):
            loss, _ = _combined_loss(cfg, backbone, adapters, ladp, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(local_adapters)
        new_local, new_opt = adamw_update(
            grads, opt_state, local_adapters, lr=hp.lr, grad_clip=hp.grad_clip
        )
        return new_local, new_opt, loss

    return jax.jit(step)


def local_update(
    cfg,
    backbone,
    state: ClientState,
    batches: List[Batch],
    hp: HyperParams,
    strategy,
    global_adapters,
    round_idx: int,
) -> Tuple[ClientState, Dict]:
    """Run T local steps (+ FIM estimation) for one client. Returns metrics."""
    from repro.strategies.base import get_strategy

    strategy = get_strategy(strategy)
    # scheduling hooks see the client's own participation count, not the
    # global round index: under partial participation a client's first
    # round may be round r > 0, and its download/warmup schedule must
    # start then (with full participation the two indices coincide).
    participated = state.rounds_participated
    # round start: adopt the global adapters (Alg. 1 ClientUpdate line 1)
    # unless the strategy skips the download (LocFT after its first round).
    if strategy.downloads_global(participated):
        adapters = jax.tree.map(jnp.copy, global_adapters)
    else:
        adapters = state.adapters
    opt_state = state.opt_state

    # personal-adapter warmup rounds (FedDPA-F)
    local_adapters = state.local_adapters
    if local_adapters is not None and strategy.local_warmup(participated, hp):
        lstep = make_local_adapter_step(cfg, hp)
        lopt = adamw_init(local_adapters)
        for batch in batches[: hp.local_steps]:
            local_adapters, lopt, _ = lstep(backbone, adapters, local_adapters, lopt, batch)

    step_fn = make_train_step(cfg, strategy, hp)
    ef_sum = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), adapters)
    ef_cnt = jnp.zeros((), jnp.float32)
    losses = []
    for t in range(hp.local_steps):
        batch = batches[t % len(batches)]
        adapters, opt_state, loss, ef_sum, ef_cnt = step_fn(
            backbone, adapters, local_adapters, opt_state, batch, global_adapters,
            ef_sum, ef_cnt,
        )
        losses.append(float(loss))

    fisher = None
    if strategy.wants_fisher == "dedicated":
        gfn = make_fisher_grad(cfg)
        fisher = fisher_pass(
            lambda adp, b: gfn(backbone, adp, b),
            adapters,
            batches[: hp.fisher_batches],
        )
    elif strategy.wants_fisher == "streaming":
        acc = FisherAccumulator(sum_sq=ef_sum, count=ef_cnt)
        fisher = acc.finalize()

    new_state = dataclasses.replace(
        state,
        adapters=adapters,
        opt_state=opt_state,
        local_adapters=local_adapters,
        fisher=fisher,
        rounds_participated=participated + 1,
    )
    if losses:
        metrics = {"loss_first": losses[0], "loss_last": losses[-1],
                   "loss_mean": sum(losses) / len(losses)}
    else:  # hp.local_steps == 0: a no-op round must stay NaN-free
        metrics = {"loss_first": 0.0, "loss_last": 0.0, "loss_mean": 0.0}
    return new_state, metrics


@functools.lru_cache(maxsize=64)
def _make_eval_fn(cfg, has_local: bool) -> Callable:
    def acc_fn(backbone, adapters, local_adapters, batch):
        embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
            cfg, backbone, adapters, batch
        )
        if has_local:
            kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=False)
            if "text" in local_adapters:
                embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
            if enc is not None and "image" in local_adapters:
                enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
        from repro.models import model as model_lib
        from repro.models.layers import token_accuracy

        hidden, _ = model_lib.forward(cfg, backbone, embeds, positions, enc)
        lg = model_lib.logits(cfg, backbone, hidden)
        return token_accuracy(lg, labels, mask)

    return jax.jit(acc_fn)


def eval_client(cfg, backbone, adapters, local_adapters, batches: List[Batch]) -> float:
    """Answer-token accuracy under teacher forcing (the VQA-accuracy proxy)."""
    acc_fn = _make_eval_fn(cfg, local_adapters is not None)
    accs = [float(acc_fn(backbone, adapters, local_adapters, b)) for b in batches]
    return sum(accs) / max(len(accs), 1)
