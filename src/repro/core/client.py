"""Client-side local tuning (Alg. 1, ClientUpdate).

Each client trains ONLY its NanoAdapters (optionally a dual local adapter for
the FedDPA-F baseline). The backbone is a frozen constant — gradients are
taken w.r.t. the adapter pytree alone, so the server-hosted LLM weights are
never perturbed and nothing model-sized is ever shipped.

Strategy-specific behaviour:
    fednano     adamw on adapters; dedicated Fisher pass after local training
    fednano_ef  same, but the FIM is accumulated from training-step grads
                (zero extra passes — paper Tab. 7 trade-off)
    fedavg      plain local adamw
    fedprox     + (μ/2)·‖θ − θ_global‖² proximal term in the local loss
    feddpa_f    dual adapters: frozen personal adapter (trained in round 1
                only) composed after the shared global adapter
    locft       local-only; no upload, no download after round 0
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import adapters as adapters_lib
from repro.core.fisher import FisherAccumulator, fisher_pass
from repro.core.types import Batch
from repro.optim import adamw_init, adamw_update
from repro.utils import tree_sq_norm, tree_sub


@dataclass(frozen=True)
class HyperParams:
    lr: float = 1e-3
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    local_steps: int = 10          # T local steps per round (paper: 1 epoch)
    prox_mu: float = 0.01          # FedProx proximal coefficient
    fisher_batches: int = 4        # batches for the dedicated FIM pass
    dpa_warmup_rounds: int = 1     # FedDPA-F: rounds that train the local adapter
    # --- beyond-paper extensions (repro.core.{compression,privacy}) ---
    compress_uploads: bool = False # int8 delta quantization + error feedback
    dp_clip: float = 0.0           # client-level DP: L2 clip of the delta (0 = off)
    dp_noise: float = 0.0          # client-level DP: Gaussian noise multiplier


@dataclass
class ClientState:
    cid: int
    adapters: Dict            # global/shared NanoAdapters (uploaded)
    opt_state: Any
    n_examples: int
    local_adapters: Optional[Dict] = None   # FedDPA-F personal adapter
    fisher: Optional[Dict] = None           # last computed diagonal FIM
    ef_acc: Optional[FisherAccumulator] = None
    comp_error: Optional[Dict] = None       # int8-compression error feedback


def init_client(key, cfg, cid: int, n_examples: int, strategy: str) -> ClientState:
    k1, k2 = jax.random.split(key)
    adp = adapters_lib.init_nanoedge(k1, cfg)
    local = adapters_lib.init_nanoedge(k2, cfg) if strategy == "feddpa_f" else None
    return ClientState(
        cid=cid,
        adapters=adp,
        opt_state=adamw_init(adp),
        n_examples=n_examples,
        local_adapters=local,
    )


def _combined_loss(cfg, backbone, adapters, local_adapters, batch):
    """FedDPA composition: shared adapter then personal adapter."""
    if local_adapters is None:
        return adapters_lib.fednano_loss(cfg, backbone, adapters, batch)
    # compose: run NanoEdge with the shared adapters, then apply the personal
    # adapters on the resulting embeddings (dual-adapter design).
    embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
        cfg, backbone, adapters, batch
    )
    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=cfg.use_pallas)
    if "text" in local_adapters:
        embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
    if enc is not None and "image" in local_adapters:
        enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
    from repro.models import model as model_lib

    loss, aux = model_lib.loss_fn(cfg, backbone, embeds, positions, labels, mask, enc)
    return loss, aux


@functools.lru_cache(maxsize=64)
def make_train_step(cfg, strategy: str, hp: HyperParams) -> Callable:
    """Jitted local train step, shared across clients (compiled once)."""

    def step(backbone, adapters, local_adapters, opt_state, batch, global_ref, ef_sum, ef_cnt):
        def loss_fn(adp):
            loss, aux = _combined_loss(cfg, backbone, adp, local_adapters, batch)
            if strategy == "fedprox":
                loss = loss + 0.5 * hp.prox_mu * tree_sq_norm(tree_sub(adp, global_ref))
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(adapters)
        new_adapters, new_opt = adamw_update(
            grads, opt_state, adapters,
            lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
        )
        # streaming (EF) Fisher accumulation — free squared grads
        new_ef_sum = jax.tree.map(
            lambda s, g: s + jnp.square(g.astype(s.dtype)), ef_sum, grads
        )
        return new_adapters, new_opt, loss, new_ef_sum, ef_cnt + 1.0

    return jax.jit(step)


@functools.lru_cache(maxsize=64)
def make_fisher_grad(cfg) -> Callable:
    """grad of the plain task loss (no prox) — used by the dedicated FIM pass."""

    def gfn(backbone, adapters, batch):
        def loss_fn(adp):
            loss, _ = adapters_lib.fednano_loss(cfg, backbone, adp, batch)
            return loss

        return jax.grad(loss_fn)(adapters)

    return jax.jit(gfn)


@functools.lru_cache(maxsize=64)
def make_local_adapter_step(cfg, hp: HyperParams) -> Callable:
    """FedDPA-F warmup: train the PERSONAL adapter (shared adapter frozen)."""

    def step(backbone, adapters, local_adapters, opt_state, batch):
        def loss_fn(ladp):
            loss, _ = _combined_loss(cfg, backbone, adapters, ladp, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(local_adapters)
        new_local, new_opt = adamw_update(
            grads, opt_state, local_adapters, lr=hp.lr, grad_clip=hp.grad_clip
        )
        return new_local, new_opt, loss

    return jax.jit(step)


def local_update(
    cfg,
    backbone,
    state: ClientState,
    batches: List[Batch],
    hp: HyperParams,
    strategy: str,
    global_adapters,
    round_idx: int,
) -> Tuple[ClientState, Dict]:
    """Run T local steps (+ FIM estimation) for one client. Returns metrics."""
    # round start: adopt the global adapters (Alg. 1 ClientUpdate line 1);
    # LocFT never re-downloads after initialization.
    if strategy == "locft" and round_idx > 0:
        adapters = state.adapters
    else:
        adapters = jax.tree.map(jnp.copy, global_adapters)
    opt_state = state.opt_state

    # FedDPA-F: personal-adapter warmup rounds
    local_adapters = state.local_adapters
    if strategy == "feddpa_f" and round_idx < hp.dpa_warmup_rounds:
        lstep = make_local_adapter_step(cfg, hp)
        lopt = adamw_init(local_adapters)
        for batch in batches[: hp.local_steps]:
            local_adapters, lopt, _ = lstep(backbone, adapters, local_adapters, lopt, batch)

    step_fn = make_train_step(cfg, strategy, hp)
    ef_sum = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), adapters)
    ef_cnt = jnp.zeros((), jnp.float32)
    losses = []
    for t in range(hp.local_steps):
        batch = batches[t % len(batches)]
        adapters, opt_state, loss, ef_sum, ef_cnt = step_fn(
            backbone, adapters, local_adapters, opt_state, batch, global_adapters,
            ef_sum, ef_cnt,
        )
        losses.append(float(loss))

    fisher = None
    if strategy == "fednano":
        gfn = make_fisher_grad(cfg)
        fisher = fisher_pass(
            lambda adp, b: gfn(backbone, adp, b),
            adapters,
            batches[: hp.fisher_batches],
        )
    elif strategy == "fednano_ef":
        acc = FisherAccumulator(sum_sq=ef_sum, count=ef_cnt)
        fisher = acc.finalize()

    new_state = dataclasses.replace(
        state,
        adapters=adapters,
        opt_state=opt_state,
        local_adapters=local_adapters,
        fisher=fisher,
    )
    metrics = {"loss_first": losses[0], "loss_last": losses[-1], "loss_mean": sum(losses) / len(losses)}
    return new_state, metrics


@functools.lru_cache(maxsize=64)
def _make_eval_fn(cfg, has_local: bool) -> Callable:
    def acc_fn(backbone, adapters, local_adapters, batch):
        embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
            cfg, backbone, adapters, batch
        )
        if has_local:
            kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=False)
            if "text" in local_adapters:
                embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
            if enc is not None and "image" in local_adapters:
                enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
        from repro.models import model as model_lib
        from repro.models.layers import token_accuracy

        hidden, _ = model_lib.forward(cfg, backbone, embeds, positions, enc)
        lg = model_lib.logits(cfg, backbone, hidden)
        return token_accuracy(lg, labels, mask)

    return jax.jit(acc_fn)


def eval_client(cfg, backbone, adapters, local_adapters, batches: List[Batch]) -> float:
    """Answer-token accuracy under teacher forcing (the VQA-accuracy proxy)."""
    acc_fn = _make_eval_fn(cfg, local_adapters is not None)
    accs = [float(acc_fn(backbone, adapters, local_adapters, b)) for b in batches]
    return sum(accs) / max(len(accs), 1)
