"""Client-side local tuning (Alg. 1, ClientUpdate).

Each client trains ONLY its NanoAdapters (optionally a dual local adapter for
the FedDPA-F baseline). The backbone is a frozen constant — gradients are
taken w.r.t. the adapter pytree alone, so the server-hosted LLM weights are
never perturbed and nothing model-sized is ever shipped.

Strategy-specific behaviour is injected through the ``repro.strategies``
hooks (``wrap_local_loss``, ``wants_fisher``, ``downloads_global``,
``local_warmup``); this module only knows how to run T adamw steps over a
wrapped objective and estimate the diagonal FIM. ``strategy`` arguments
accept either a registered name ("fednano", "fedprox", …) or a ``Strategy``
instance — names are resolved through the registry.

Three execution paths share the same step bodies (one source of numerics):

  * ``local_update``       — one client, Python loop over T jitted steps.
  * ``local_update_many``  — a cohort of homogeneous clients at once:
    per-client state pytrees are stacked along a new leading axis and the
    whole round runs as ``vmap`` (over clients) of ``lax.scan`` (over local
    steps), so a 1k-client round costs one dispatch instead of 1k·T.
  * the same stacked layout partitioned over a 1-D ``("clients",)`` device
    mesh: ``make_many_update(..., mesh=...)`` wraps the identical vmapped
    body in ``shard_map``, so every device runs K/D clients in parallel
    with unchanged per-client arithmetic (the sharded engine pads ragged
    cohorts by repeating the last row; padding rows are sliced off before
    any state, metric, or byte leaves this module).

``local_update_many`` is itself split into ``prepare_cohort`` (host-side
validation + stacking + device placement), ``launch_cohort`` (the async
device dispatch), and ``collect_cohort`` (device→host unstack + state
rebuild), so the round engine can double-buffer: prepare cohort k+1 on the
host while cohort k computes on the devices.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import adapters as adapters_lib
from repro.core.fisher import FisherAccumulator, fisher_pass
from repro.core.types import Batch
from repro.optim import adamw_init, adamw_update
from repro.utils import tree_stack  # noqa: F401  (re-export for tests)


@dataclass(frozen=True)
class HyperParams:
    lr: float = 1e-3
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    local_steps: int = 10          # T local steps per round (paper: 1 epoch)
    prox_mu: float = 0.01          # FedProx proximal coefficient
    fisher_batches: int = 4        # batches for the dedicated FIM pass
    dpa_warmup_rounds: int = 1     # FedDPA-F: rounds that train the local adapter
    # --- beyond-paper extensions (repro.core.{compression,privacy}) ---
    compress_uploads: bool = False # int8 delta quantization + error feedback
    dp_clip: float = 0.0           # client-level DP: L2 clip of the delta (0 = off)
    dp_noise: float = 0.0          # client-level DP: Gaussian noise multiplier


@dataclass
class ClientState:
    cid: int
    adapters: Dict            # global/shared NanoAdapters (uploaded)
    opt_state: Any
    n_examples: int
    local_adapters: Optional[Dict] = None   # FedDPA-F personal adapter
    fisher: Optional[Dict] = None           # last computed diagonal FIM
    rounds_participated: int = 0            # local_update calls so far (drives
                                            # download/warmup under sampling)
    local_opt_state: Any = None             # personal-adapter AdamW moments,
                                            # carried across warmup rounds


def init_client(key, cfg, cid: int, n_examples: int, strategy) -> ClientState:
    """Build a client via the strategy's ``init_client`` hook."""
    from repro.strategies.base import get_strategy

    return get_strategy(strategy).init_client(key, cfg, cid, n_examples)


@functools.lru_cache(maxsize=16)
def _make_batched_init(cfg, dual: bool) -> Callable:
    """Jitted vmapped variant of the base ``Strategy.init_client`` body.

    jax.random is counter-based (threefry): ``vmap(split)`` /
    ``vmap(init_nanoedge)`` over stacked keys draw bit-identical values to K
    sequential per-key calls, so the fast path is exact, not approximate.
    """

    def one(key):
        k1, k2 = jax.random.split(key)
        adp = adapters_lib.init_nanoedge(k1, cfg)
        local = adapters_lib.init_nanoedge(k2, cfg) if dual else None
        return adp, adamw_init(adp), local

    return jax.jit(jax.vmap(one))


def init_clients_batched(strategy, keys, cfg, cids, n_examples) -> List[ClientState]:
    """Batch-initialize a homogeneous cohort in one device dispatch.

    Per-client ``init_client`` costs O(K) dispatches and dominates setup
    wall-clock at 10k clients; this stacks the PRNG keys and runs ONE jitted
    vmap, then unstacks through numpy views. Only valid for strategies using
    the base ``Strategy.init_client`` body (the ``Strategy.init_clients``
    hook guards this and falls back to the loop otherwise).
    """
    k = len(cids)
    assert len(keys) == k and len(n_examples) == k
    adp, opt, local = _make_batched_init(cfg, bool(strategy.dual_adapters))(
        jnp.stack(list(keys)))
    adp_list = _host_unstack(adp, k)
    opt_list = _host_unstack(opt, k)
    local_list = (_host_unstack(local, k)
                  if strategy.dual_adapters else [None] * k)
    return [
        ClientState(cid=cid, adapters=adp_list[i], opt_state=opt_list[i],
                    n_examples=n, local_adapters=local_list[i])
        for i, (cid, n) in enumerate(zip(cids, n_examples))
    ]


def client_ref_like(state: ClientState) -> ClientState:
    """Reference structures for restoring a checkpointed ``ClientState``.

    A freshly-initialized client may carry ``None`` where a checkpointed one
    holds arrays (the FIM after its first round, the personal-adapter AdamW
    moments after warmup). This fills those slots with structure templates —
    fisher trees are float32 adapter-shaped (both the dedicated pass and the
    streaming EF estimator accumulate squared grads in float32), and the
    personal optimizer template is a fresh ``adamw_init`` — so strict
    shape/dtype restoration has something to restore into. Values are
    irrelevant; only structure, shapes, and dtypes matter.
    """
    fisher = state.fisher
    if fisher is None:
        fisher = jax.tree.map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), state.adapters)
    local_opt_state = state.local_opt_state
    if local_opt_state is None and state.local_adapters is not None:
        local_opt_state = adamw_init(state.local_adapters)
    return dataclasses.replace(
        state, fisher=fisher, local_opt_state=local_opt_state)


def _combined_loss(cfg, backbone, adapters, local_adapters, batch):
    """FedDPA composition: shared adapter then personal adapter."""
    if local_adapters is None:
        return adapters_lib.fednano_loss(cfg, backbone, adapters, batch)
    # compose: run NanoEdge with the shared adapters, then apply the personal
    # adapters on the resulting embeddings (dual-adapter design).
    embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
        cfg, backbone, adapters, batch
    )
    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=cfg.use_pallas)
    if "text" in local_adapters:
        embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
    if enc is not None and "image" in local_adapters:
        enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
    from repro.models import model as model_lib

    loss, aux = model_lib.loss_fn(cfg, backbone, embeds, positions, labels, mask, enc)
    return loss, aux


def _train_step_body(cfg, strategy, hp, backbone, adapters, local_adapters,
                     opt_state, batch, global_ref, ef_sum, ef_cnt):
    """One local AdamW step on the shared adapters (pure; traced by both the
    per-client jitted step and the vmap/scan engine — single numerics source)."""

    def base_loss(adp):
        return _combined_loss(cfg, backbone, adp, local_adapters, batch)

    loss_fn = strategy.wrap_local_loss(base_loss, hp, global_ref)
    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(adapters)
    new_adapters, new_opt = adamw_update(
        grads, opt_state, adapters,
        lr=hp.lr, weight_decay=hp.weight_decay, grad_clip=hp.grad_clip,
    )
    # streaming (EF) Fisher accumulation — free squared grads
    new_ef_sum = jax.tree.map(
        lambda s, g: s + jnp.square(g.astype(s.dtype)), ef_sum, grads
    )
    return new_adapters, new_opt, loss, new_ef_sum, ef_cnt + 1.0


def _fisher_grad_body(cfg, backbone, adapters, batch):
    """grad of the plain task loss (no prox) — used by the dedicated FIM pass."""

    def loss_fn(adp):
        loss, _ = adapters_lib.fednano_loss(cfg, backbone, adp, batch)
        return loss

    return jax.grad(loss_fn)(adapters)


def _local_adapter_step_body(cfg, hp, backbone, adapters, local_adapters, opt_state, batch):
    """FedDPA-F warmup step: train the PERSONAL adapter (shared adapter frozen)."""

    def loss_fn(ladp):
        loss, _ = _combined_loss(cfg, backbone, adapters, ladp, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(local_adapters)
    new_local, new_opt = adamw_update(
        grads, opt_state, local_adapters, lr=hp.lr, grad_clip=hp.grad_clip
    )
    return new_local, new_opt, loss


@functools.lru_cache(maxsize=64)
def make_train_step(cfg, strategy, hp: HyperParams) -> Callable:
    """Jitted local train step, shared across clients (compiled once per
    (cfg, strategy, hp) — strategies are frozen dataclasses, so value-equal
    instances hit the same cache entry)."""

    def step(backbone, adapters, local_adapters, opt_state, batch, global_ref, ef_sum, ef_cnt):
        return _train_step_body(cfg, strategy, hp, backbone, adapters,
                                local_adapters, opt_state, batch, global_ref,
                                ef_sum, ef_cnt)

    return jax.jit(step)


@functools.lru_cache(maxsize=64)
def make_fisher_grad(cfg) -> Callable:
    def gfn(backbone, adapters, batch):
        return _fisher_grad_body(cfg, backbone, adapters, batch)

    return jax.jit(gfn)


@functools.lru_cache(maxsize=64)
def make_local_adapter_step(cfg, hp: HyperParams) -> Callable:
    def step(backbone, adapters, local_adapters, opt_state, batch):
        return _local_adapter_step_body(cfg, hp, backbone, adapters,
                                        local_adapters, opt_state, batch)

    return jax.jit(step)


def local_update(
    cfg,
    backbone,
    state: ClientState,
    batches: List[Batch],
    hp: HyperParams,
    strategy,
    global_adapters,
    round_idx: int,
) -> Tuple[ClientState, Dict]:
    """Run T local steps (+ FIM estimation) for one client. Returns metrics."""
    from repro.strategies.base import get_strategy

    strategy = get_strategy(strategy)
    # scheduling hooks see the client's own participation count, not the
    # global round index: under partial participation a client's first
    # round may be round r > 0, and its download/warmup schedule must
    # start then (with full participation the two indices coincide).
    participated = state.rounds_participated
    # round start: adopt the global adapters (Alg. 1 ClientUpdate line 1)
    # unless the strategy skips the download (LocFT after its first round).
    if strategy.downloads_global(participated):
        adapters = jax.tree.map(jnp.copy, global_adapters)
    else:
        adapters = state.adapters
    opt_state = state.opt_state

    # personal-adapter warmup rounds (FedDPA-F). The optimizer state is
    # carried in ClientState across rounds — re-initializing it every warmup
    # round would silently discard the Adam moments between rounds.
    local_adapters = state.local_adapters
    local_opt_state = state.local_opt_state
    if local_adapters is not None and strategy.local_warmup(participated, hp):
        lstep = make_local_adapter_step(cfg, hp)
        if local_opt_state is None:
            local_opt_state = adamw_init(local_adapters)
        for batch in batches[: hp.local_steps]:
            local_adapters, local_opt_state, _ = lstep(
                backbone, adapters, local_adapters, local_opt_state, batch
            )

    step_fn = make_train_step(cfg, strategy, hp)
    ef_sum = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), adapters)
    ef_cnt = jnp.zeros((), jnp.float32)
    losses = []
    for t in range(hp.local_steps):
        batch = batches[t % len(batches)]
        adapters, opt_state, loss, ef_sum, ef_cnt = step_fn(
            backbone, adapters, local_adapters, opt_state, batch, global_adapters,
            ef_sum, ef_cnt,
        )
        losses.append(float(loss))

    fisher = None
    if strategy.wants_fisher == "dedicated":
        gfn = make_fisher_grad(cfg)
        fisher = fisher_pass(
            lambda adp, b: gfn(backbone, adp, b),
            adapters,
            batches[: hp.fisher_batches],
        )
    elif strategy.wants_fisher == "streaming":
        acc = FisherAccumulator(sum_sq=ef_sum, count=ef_cnt)
        fisher = acc.finalize()

    new_state = dataclasses.replace(
        state,
        adapters=adapters,
        opt_state=opt_state,
        local_adapters=local_adapters,
        local_opt_state=local_opt_state,
        fisher=fisher,
        rounds_participated=participated + 1,
    )
    if losses:
        metrics = {"loss_first": losses[0], "loss_last": losses[-1],
                   "loss_mean": sum(losses) / len(losses)}
    else:  # hp.local_steps == 0: a no-op round must stay NaN-free
        metrics = {"loss_first": 0.0, "loss_last": 0.0, "loss_mean": 0.0}
    return new_state, metrics


# ---------------------------------------------------------------------------
# vectorized many-client path (engine="vmap")
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_many_update(cfg, strategy, hp: HyperParams, *, downloads: bool,
                     warmup: bool, has_local: bool, train_t: int, warm_t: int,
                     fish_t: int, shared_batches: bool,
                     mesh: Optional[Mesh] = None) -> Callable:
    """Jitted whole-round update for a stacked cohort.

    One compiled program runs ``vmap`` over the client axis of ``lax.scan``
    over local steps, reusing the exact per-client step bodies of the
    sequential path. Static knobs (download/warmup flags, step counts,
    whether every client trains on the same batches) are part of the cache
    key; array shapes carry the cohort size K.

    Batch pytrees arrive client-major: leaves ``(K, T, B, ...)``, or
    ``(T, B, ...)`` when ``shared_batches`` (then broadcast via in_axes=None
    instead of materializing K copies).

    With ``mesh`` (a 1-D ``("clients",)`` mesh from
    :func:`repro.sharding.client_mesh`), the vmapped body is wrapped in
    ``shard_map``: client-stacked arguments are partitioned over the mesh
    axis (K must divide the device count — the caller pads), the backbone /
    global adapters / shared batches are replicated, and each device runs
    its K/D clients with per-client arithmetic identical to the plain vmap
    path (clients never interact inside a round, so partitioning the client
    axis is numerics-free).
    """

    def one_client(backbone, global_adapters, adapters, opt_state, local,
                   lopt, train_b, warm_b, fish_b):
        if downloads:
            adapters = global_adapters  # vmap broadcast == per-client copy
        if warmup:
            def wstep(carry, batch):
                la, lo = carry
                la, lo, wloss = _local_adapter_step_body(
                    cfg, hp, backbone, adapters, la, lo, batch)
                return (la, lo), wloss

            (local, lopt), _ = jax.lax.scan(wstep, (local, lopt), warm_b)

        ef_sum = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), adapters)
        ef_cnt = jnp.zeros((), jnp.float32)
        if train_t > 0:
            def tstep(carry, batch):
                adp, opt, es, ec = carry
                adp, opt, loss, es, ec = _train_step_body(
                    cfg, strategy, hp, backbone, adp, local, opt, batch,
                    global_adapters, es, ec)
                return (adp, opt, es, ec), loss

            (adapters, opt_state, ef_sum, ef_cnt), losses = jax.lax.scan(
                tstep, (adapters, opt_state, ef_sum, ef_cnt), train_b)
        else:
            losses = jnp.zeros((0,), jnp.float32)

        fisher = None
        if strategy.wants_fisher == "dedicated" and fish_t == 0:
            # fisher_pass over zero batches: the eps floor, nothing else
            fisher = jax.tree.map(lambda x: jnp.full_like(x, 1e-8), adapters)
        elif strategy.wants_fisher == "dedicated":
            def fstep(acc, batch):
                s, c = acc
                g = _fisher_grad_body(cfg, backbone, adapters, batch)
                s = jax.tree.map(
                    lambda ss, gg: ss + jnp.square(gg.astype(ss.dtype)), s, g)
                return (s, c + 1.0), None

            f0 = (jax.tree.map(jnp.zeros_like, adapters),
                  jnp.zeros((), jnp.float32))
            (fsum, fcnt), _ = jax.lax.scan(fstep, f0, fish_b)
            c = jnp.maximum(fcnt, 1.0)
            fisher = jax.tree.map(lambda s: s / c + 1e-8, fsum)
        elif strategy.wants_fisher == "streaming":
            c = jnp.maximum(ef_cnt, 1.0)
            fisher = jax.tree.map(lambda s: s / c + 1e-8, ef_sum)
        return adapters, opt_state, local, lopt, fisher, losses

    batch_ax = None if shared_batches else 0
    vm = jax.vmap(one_client,
                  in_axes=(None, None, 0, 0, 0, 0, batch_ax, batch_ax, batch_ax))
    if mesh is not None:
        from jax.experimental.shard_map import shard_map

        rep, shd = P(), P(*(a for a in mesh.axis_names))
        bspec = rep if shared_batches else shd
        vm = shard_map(
            vm, mesh=mesh,
            in_specs=(rep, rep, shd, shd, shd, shd, bspec, bspec, bspec),
            out_specs=shd, check_rep=False)
    return jax.jit(vm)


def _host_stack(trees, *, to_device: bool = True):
    """``tree_stack`` for the host side of the vmap path.

    ``jnp.stack`` over K device arrays and per-leaf device ops cost
    O(K·leaves) dispatches — at 10k clients that dwarfs the round itself. On
    the CPU backend ``np.asarray`` of a jax array is a zero-copy view, so
    stacking through numpy is one C-level memcpy + one transfer per leaf.

    ``to_device=False`` keeps the stacked leaves as numpy: the sharded path
    scatters them straight to the mesh with one ``device_put`` per leaf, so
    the intermediate copy onto the default device would be pure waste.
    """
    td = jax.tree.structure(trees[0])
    # one batched device_get (single sync) beats per-leaf np.asarray, which
    # pays ~100µs of sync overhead per call — O(K·leaves) of them here
    flat = jax.device_get([jax.tree.flatten(t)[0] for t in trees])
    conv = jnp.asarray if to_device else (lambda x: x)
    leaves = [conv(np.stack(col)) for col in zip(*flat)]
    return jax.tree.unflatten(td, leaves)


def _host_unstack(tree, n: int):
    """Inverse of :func:`_host_stack`: numpy views per client, no device ops.

    The returned per-client leaves are numpy arrays (views into the stacked
    result); downstream jax ops convert them back for free on CPU.
    """
    leaves, td = jax.tree.flatten(tree)
    host = jax.device_get(leaves)
    return [jax.tree.unflatten(td, [h[i] for h in host]) for i in range(n)]


def _stack_batch_rows(batch_lists: Sequence[List[Batch]], picks, *,
                      shared: bool, to_device: bool = True):
    """Stack per-client batch selections into scan xs.

    ``picks(batches)`` yields the Batch sequence one client scans over.
    Returns leaves ``(T, B, ...)`` when ``shared`` (every client trains on
    the same list object — broadcast instead of K copies), else
    ``(K, T, B, ...)``.
    """
    if shared:
        row = list(picks(batch_lists[0]))
        return _host_stack(row, to_device=to_device) if row else None
    rows = []
    for bl in batch_lists:
        row = list(picks(bl))
        if not row:
            return None
        rows.append(_host_stack(row, to_device=False))
    return _host_stack(rows, to_device=to_device)


@dataclass
class PreparedCohort:
    """Host-side product of :func:`prepare_cohort`: stacked (and, under a
    mesh, padded + device-placed) inputs plus the compiled update fn.

    ``k`` is the number of *real* clients; padded rows (``pad_to`` under a
    mesh) duplicate the last real client and are sliced off in
    :func:`collect_cohort` before any state, metric, or byte accounting
    sees them.
    """

    states: List[ClientState]
    k: int
    fn: Callable
    args: tuple                  # (adapters0, opt0, local0, lopt0, xs...)
    has_local: bool
    warmup: bool
    train_t: int
    wants_fisher: Optional[str]
    mesh: Optional[Mesh] = None


@dataclass
class LaunchedCohort:
    """An in-flight cohort dispatch: outputs are jax async futures, so the
    host is free to prepare the next cohort while devices compute."""

    prepared: PreparedCohort
    outs: tuple


def prepare_cohort(
    cfg,
    states: List[ClientState],
    batch_lists: Sequence[List[Batch]],
    hp: HyperParams,
    strategy,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
    opt0_override=None,
    batches_override=None,
) -> PreparedCohort:
    """Validate + stack a homogeneous cohort (the host half of a dispatch).

    All clients must share the same scheduling flags this round (the engine
    groups cohorts by ``downloads_global``/``local_warmup``), the same batch
    shapes, and the same warmup/Fisher batch counts; heterogeneous cohorts
    raise ``ValueError`` (fall back to ``engine="sequential"``).

    With ``mesh`` the stacked leaves are placed with a
    ``NamedSharding(mesh, P("clients"))`` along the client axis; the cohort
    is padded up to ``pad_to`` (default: the next multiple of the mesh size)
    by repeating the last client's row. Padding rows compute and are
    discarded — they are never returned, never aggregated, never counted.

    ``opt0_override`` supplies the stacked AdamW state directly (an already
    padded, already device-placed tree — normally last round's ``new_opt``
    output for the identical chunk), skipping the host stack + transfer.
    The caller owns the invariant that it matches these clients' true
    current optimizer state; see the engine's chunk-resident opt cache.

    ``batches_override`` likewise supplies an already stacked + placed
    ``(train_xs, warm_xs, fish_xs)`` triple for this exact cohort — client
    batch lists are immutable within a run, so the engine reuses the placed
    stacks across rounds instead of re-stacking identical data every round.
    """
    from repro.sharding import CLIENT_AXIS, pad_to_multiple
    from repro.strategies.base import get_strategy

    strategy = get_strategy(strategy)
    k = len(states)
    assert k > 0

    participated = [s.rounds_participated for s in states]
    downloads = strategy.downloads_global(participated[0])
    has_local = states[0].local_adapters is not None
    warmup = has_local and strategy.local_warmup(participated[0], hp)
    for s, p in zip(states[1:], participated[1:]):
        if (strategy.downloads_global(p) != downloads
                or (s.local_adapters is not None) != has_local
                or ((s.local_adapters is not None)
                    and strategy.local_warmup(p, hp)) != warmup):
            raise ValueError(
                "local_update_many needs a cohort with uniform download/"
                "warmup schedules; group clients by these flags first")

    real_states, real_lists = states, list(batch_lists)
    if mesh is not None:
        nd = mesh.size
        width = pad_to if pad_to is not None else pad_to_multiple(k, nd)
        if width % nd != 0:
            raise ValueError(
                f"pad_to={width} must be a multiple of the mesh size {nd}")
        if width < k:
            raise ValueError(f"pad_to={width} is smaller than the cohort ({k})")
        pad = width - k
        states = states + [states[-1]] * pad
        batch_lists = list(batch_lists) + [batch_lists[-1]] * pad
    del real_states, real_lists

    warm_ts = {min(len(bl), hp.local_steps) for bl in batch_lists} if warmup else {0}
    fish_ts = ({min(len(bl), hp.fisher_batches) for bl in batch_lists}
               if strategy.wants_fisher == "dedicated" else {0})
    if len(warm_ts) > 1 or len(fish_ts) > 1:
        raise ValueError(
            "local_update_many needs uniform per-client batch counts for the "
            "warmup/Fisher passes; use engine='sequential' for ragged shards")
    warm_t, fish_t = warm_ts.pop(), fish_ts.pop()
    train_t = hp.local_steps

    shared = all(bl is batch_lists[0] for bl in batch_lists)
    # under a mesh the stacked leaves go straight from numpy to their mesh
    # shards (one device_put below); staging them on the default device
    # first would pay a second full copy of the cohort
    to_dev = mesh is None
    if batches_override is not None:
        train_xs, warm_xs, fish_xs = batches_override
    else:
        try:
            train_xs = _stack_batch_rows(
                batch_lists, lambda bl: (bl[t % len(bl)] for t in range(train_t)),
                shared=shared, to_device=to_dev)
            warm_xs = _stack_batch_rows(
                batch_lists, lambda bl: bl[:warm_t], shared=shared,
                to_device=to_dev) if warmup else None
            fish_xs = _stack_batch_rows(
                batch_lists, lambda bl: bl[:fish_t], shared=shared,
                to_device=to_dev) if fish_t else None
        except ValueError as e:  # jnp.stack shape mismatch
            raise ValueError(
                "local_update_many needs identical batch shapes across the "
                f"cohort ({e}); use engine='sequential' for ragged shards") from e
    if train_t > 0 and train_xs is None:
        raise ValueError("clients with no training batches cannot run local steps")

    adapters0 = (None if downloads
                 else _host_stack([s.adapters for s in states], to_device=to_dev))
    opt0 = (opt0_override if opt0_override is not None
            else _host_stack([s.opt_state for s in states], to_device=to_dev))
    local0 = (_host_stack([s.local_adapters for s in states], to_device=to_dev)
              if has_local else None)
    lopt0 = None
    if warmup:
        lopt0 = _host_stack([
            s.local_opt_state if s.local_opt_state is not None
            else adamw_init(s.local_adapters) for s in states
        ], to_device=to_dev)

    if mesh is not None:
        # direct host->device scatter per shard: each device receives only
        # its K/D client rows (replicated args are placed at launch)
        shd = NamedSharding(mesh, P(CLIENT_AXIS))
        rep = NamedSharding(mesh, P())
        bshard = rep if shared else shd
        adapters0 = jax.device_put(adapters0, shd) if adapters0 is not None else None
        if opt0_override is None:  # an override is already mesh-placed
            opt0 = jax.device_put(opt0, shd)
        local0 = jax.device_put(local0, shd) if local0 is not None else None
        lopt0 = jax.device_put(lopt0, shd) if lopt0 is not None else None
        if batches_override is None:
            train_xs = (jax.device_put(train_xs, bshard)
                        if train_xs is not None else None)
            warm_xs = (jax.device_put(warm_xs, bshard)
                       if warm_xs is not None else None)
            fish_xs = (jax.device_put(fish_xs, bshard)
                       if fish_xs is not None else None)

    fn = make_many_update(
        cfg, strategy, hp, downloads=downloads, warmup=warmup,
        has_local=has_local, train_t=train_t, warm_t=warm_t, fish_t=fish_t,
        shared_batches=shared, mesh=mesh)
    return PreparedCohort(
        states=states[:k], k=k, fn=fn,
        args=(adapters0, opt0, local0, lopt0, train_xs, warm_xs, fish_xs),
        has_local=has_local, warmup=warmup, train_t=train_t,
        wants_fisher=strategy.wants_fisher, mesh=mesh)


def launch_cohort(prepared: PreparedCohort, backbone, global_adapters) -> LaunchedCohort:
    """Dispatch a prepared cohort. Returns immediately (async futures): the
    caller may overlap host work with device compute before collecting.

    Under a mesh, ``backbone`` / ``global_adapters`` should already be
    replicated over the mesh (the engine places them once per run/round);
    ``device_put`` below is then a no-op, and otherwise pays one broadcast.
    """
    if prepared.mesh is not None:
        rep = NamedSharding(prepared.mesh, P())
        backbone = jax.device_put(backbone, rep)
        global_adapters = jax.device_put(global_adapters, rep)
    adapters0, opt0, local0, lopt0, train_xs, warm_xs, fish_xs = prepared.args
    outs = prepared.fn(backbone, global_adapters, adapters0, opt0, local0,
                       lopt0, train_xs, warm_xs, fish_xs)
    return LaunchedCohort(prepared=prepared, outs=outs)


def collect_cohort(launched: LaunchedCohort, *, with_opt: bool = True,
                   ) -> Tuple[List[ClientState], List[Dict]]:
    """Block on a launched cohort and rebuild per-client states + metrics.

    Only the first ``k`` (real) rows are unstacked — under a mesh the
    padded tail rows never leave this function.

    ``with_opt=False`` skips the device→host gather of the AdamW state: the
    returned states keep their (now stale) previous ``opt_state``, and the
    caller takes ownership of ``launched.outs[1]`` — the stacked new opt
    tree, still on the devices — materializing rows only when a per-client
    value is actually needed (checkpointing, cohort reshuffle, run end).
    """
    p = launched.prepared
    k = p.k
    new_adp, new_opt, new_local, new_lopt, fishers, losses = launched.outs

    adp_list = _host_unstack(new_adp, k)
    opt_list = _host_unstack(new_opt, k) if with_opt else None
    local_list = _host_unstack(new_local, k) if p.has_local else [None] * k
    lopt_list = _host_unstack(new_lopt, k) if p.warmup else [None] * k
    fisher_list = (_host_unstack(fishers, k)
                   if p.wants_fisher is not None else [None] * k)

    losses_np = (np.asarray(losses)[:k] if p.train_t > 0
                 else np.zeros((k, 0), np.float32))
    new_states, metrics = [], []
    for i, s in enumerate(p.states):
        new_states.append(dataclasses.replace(
            s,
            adapters=adp_list[i],
            opt_state=opt_list[i] if with_opt else s.opt_state,
            local_adapters=local_list[i] if p.has_local else s.local_adapters,
            local_opt_state=lopt_list[i] if p.warmup else s.local_opt_state,
            fisher=fisher_list[i],
            rounds_participated=s.rounds_participated + 1,
        ))
    return new_states, _loss_metrics(losses_np)


def _loss_metrics(losses_np) -> List[Dict]:
    """Per-client loss metrics from a (k, T) host array — identical
    arithmetic to the sequential path: python floats, summed in step order,
    so seeded metrics match bit-for-bit."""
    metrics = []
    for row in losses_np:
        ls = [float(x) for x in row]
        if ls:
            metrics.append({"loss_first": ls[0], "loss_last": ls[-1],
                            "loss_mean": sum(ls) / len(ls)})
        else:
            metrics.append({"loss_first": 0.0, "loss_last": 0.0,
                            "loss_mean": 0.0})
    return metrics


def collect_cohort_deferred(launched: LaunchedCohort,
                            ) -> Tuple[List[ClientState], Optional[jax.Array]]:
    """Collect only participation counts from a launched cohort; nothing is
    pulled off the devices.

    The adapter / optimizer / Fisher outputs stay stacked on the devices —
    the caller takes ownership of ``launched.outs`` (the sharded engine
    parks them in its chunk-resident cache and folds them straight into the
    stacked aggregation hooks). The second return value is the still-device
    ``(width, T)`` losses array (or None with no train steps): the engine
    gathers every chunk's losses in ONE batched ``device_get`` at round end
    (via :func:`loss_metrics_deferred`) instead of paying a cross-device
    sync per chunk. Returned states keep their previous (now stale)
    ``adapters``/``opt_state``/``fisher`` until the engine materializes the
    resident rows.
    """
    p = launched.prepared
    new_states = [
        dataclasses.replace(s, rounds_participated=s.rounds_participated + 1)
        for s in p.states
    ]
    return new_states, (launched.outs[5] if p.train_t > 0 else None)


def loss_metrics_deferred(loss_arrays, ks) -> List[List[Dict]]:
    """One batched gather of many chunks' device losses → per-chunk metric
    lists (same arithmetic as :func:`_loss_metrics`). ``ks`` holds each
    chunk's real (unpadded) client count; ``None`` entries (no train steps)
    yield zero-loss metrics."""
    gathered = jax.device_get([a for a in loss_arrays if a is not None])
    it = iter(gathered)
    out = []
    for a, k in zip(loss_arrays, ks):
        rows = (np.asarray(next(it))[:k] if a is not None
                else np.zeros((k, 0), np.float32))
        out.append(_loss_metrics(rows))
    return out


def local_update_many(
    cfg,
    backbone,
    states: List[ClientState],
    batch_lists: Sequence[List[Batch]],
    hp: HyperParams,
    strategy,
    global_adapters,
    *,
    mesh: Optional[Mesh] = None,
    pad_to: Optional[int] = None,
) -> Tuple[List[ClientState], List[Dict]]:
    """Vectorized ``local_update`` over a homogeneous cohort.

    The fused prepare → launch → collect path (see the module docstring for
    the pipelined variant the sharded engine uses). ``mesh`` partitions the
    stacked cohort over a ``("clients",)`` device mesh via ``shard_map``,
    padding to ``pad_to`` rows (default: next multiple of the mesh size).
    """
    prepared = prepare_cohort(cfg, states, batch_lists, hp, strategy,
                              mesh=mesh, pad_to=pad_to)
    return collect_cohort(launch_cohort(prepared, backbone, global_adapters))


@functools.lru_cache(maxsize=64)
def _make_eval_fn(cfg, has_local: bool) -> Callable:
    def acc_fn(backbone, adapters, local_adapters, batch):
        embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
            cfg, backbone, adapters, batch
        )
        if has_local:
            kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha, use_pallas=False)
            if "text" in local_adapters:
                embeds = adapters_lib.nano_adapter_apply(local_adapters["text"], embeds, **kw)
            if enc is not None and "image" in local_adapters:
                enc = adapters_lib.nano_adapter_apply(local_adapters["image"], enc, **kw)
        from repro.models import model as model_lib
        from repro.models.layers import token_accuracy

        hidden, _ = model_lib.forward(cfg, backbone, embeds, positions, enc)
        lg = model_lib.logits(cfg, backbone, hidden)
        return token_accuracy(lg, labels, mask)

    return jax.jit(acc_fn)


def eval_client(cfg, backbone, adapters, local_adapters, batches: List[Batch]) -> float:
    """Answer-token accuracy under teacher forcing (the VQA-accuracy proxy)."""
    acc_fn = _make_eval_fn(cfg, local_adapters is not None)
    accs = [float(acc_fn(backbone, adapters, local_adapters, b)) for b in batches]
    return sum(accs) / max(len(accs), 1)
