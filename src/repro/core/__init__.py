"""FedNano core: the paper's contribution as a composable JAX module."""
from repro.core import adapters, aggregation, client, comm, federated, fisher, server, split, types
from repro.core.adapters import (
    fednano_loss,
    init_nano_adapter,
    init_nanoedge,
    nano_adapter_apply,
    nanoedge_forward,
)
from repro.core.aggregation import STRATEGIES, aggregate, fedavg, fisher_merge
from repro.core.client import ClientState, HyperParams, init_client, local_update
from repro.core.failures import FailureModel
from repro.core.federated import FederatedResult, run_centralized, run_federated
from repro.core.fisher import FisherAccumulator, fisher_pass
from repro.core.server import ServerState, init_server, server_aggregate
from repro.core.types import Batch

__all__ = [
    "adapters",
    "aggregation",
    "client",
    "comm",
    "federated",
    "fisher",
    "server",
    "split",
    "types",
    "fednano_loss",
    "init_nano_adapter",
    "init_nanoedge",
    "nano_adapter_apply",
    "nanoedge_forward",
    "STRATEGIES",
    "aggregate",
    "fedavg",
    "fisher_merge",
    "ClientState",
    "HyperParams",
    "init_client",
    "local_update",
    "FailureModel",
    "FederatedResult",
    "run_centralized",
    "run_federated",
    "FisherAccumulator",
    "fisher_pass",
    "ServerState",
    "init_server",
    "server_aggregate",
    "Batch",
]
