"""Shared datatypes for the FedNano core."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax


class Batch(NamedTuple):
    """One multimodal VQA batch (image-question-answer triplets).

    tokens  (B, S) int32   — question+answer token ids (client tokenizer)
    labels  (B, S) int32   — next-token targets (shifted)
    mask    (B, S) f32     — 1.0 on supervised (answer) positions
    patches (B, M, F) f32  — stubbed frontend patch/frame embeddings, or None
    """

    tokens: jax.Array
    labels: jax.Array
    mask: jax.Array
    patches: Optional[jax.Array] = None
