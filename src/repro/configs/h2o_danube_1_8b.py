"""h2o-danube-1.8b — dense decoder, llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L, d_model=2560, 32 heads (GQA kv=8), d_ff=6912,
vocab=32000, SWA window 4096 (mistral-style).
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        max_seq_len=16384,
        pos_type="rope",
        rope_theta=10000.0,
        sliding_window=4096,
        norm="rmsnorm",
        act="swiglu",
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
