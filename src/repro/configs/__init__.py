"""Architecture registry: ``--arch <id>`` -> ModelConfig.

The 10 assigned architectures (public-literature pool) plus the paper's own
two MLLM backbones. ``get_config(id)`` returns the FULL config;
``get_smoke_config(id)`` the reduced same-family smoke variant.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs.base import (
    INPUT_SHAPES,
    AdapterConfig,
    InputShape,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    reduced,
)

from repro.configs import (  # noqa: E402
    glm4_9b,
    grok_1_314b,
    h2o_danube_1_8b,
    internlm2_20b,
    llama4_scout_17b_a16e,
    llava15_7b,
    mamba2_130m,
    minigpt4_7b,
    qwen1_5_4b,
    qwen2_vl_72b,
    recurrentgemma_9b,
    whisper_base,
)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    "h2o-danube-1.8b": h2o_danube_1_8b.config,
    "qwen1.5-4b": qwen1_5_4b.config,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.config,
    "recurrentgemma-9b": recurrentgemma_9b.config,
    "qwen2-vl-72b": qwen2_vl_72b.config,
    "grok-1-314b": grok_1_314b.config,
    "mamba2-130m": mamba2_130m.config,
    "glm4-9b": glm4_9b.config,
    "whisper-base": whisper_base.config,
    "internlm2-20b": internlm2_20b.config,
    # the paper's own backbones
    "llava-1.5-7b": llava15_7b.config,
    "minigpt4-7b": minigpt4_7b.config,
}

ASSIGNED_ARCHS = [
    "h2o-danube-1.8b",
    "qwen1.5-4b",
    "llama4-scout-17b-a16e",
    "recurrentgemma-9b",
    "qwen2-vl-72b",
    "grok-1-314b",
    "mamba2-130m",
    "glm4-9b",
    "whisper-base",
    "internlm2-20b",
]

PAPER_ARCHS = ["llava-1.5-7b", "minigpt4-7b"]


def list_archs():
    return list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch]()


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "RGLRUConfig",
    "AdapterConfig",
    "InputShape",
    "INPUT_SHAPES",
    "ASSIGNED_ARCHS",
    "PAPER_ARCHS",
    "list_archs",
    "get_config",
    "get_smoke_config",
    "reduced",
]
