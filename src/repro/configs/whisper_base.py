"""whisper-base — encoder-decoder audio backbone, conv frontend STUBBED.

[arXiv:2212.04356] 6 encoder + 6 decoder layers, d_model=512, 8 heads (MHA),
d_ff=2048, vocab=51865, learned positions, LayerNorm + GELU MLP,
encoder memory fixed at 1500 frames.

Per the assignment carve-out the mel-spectrogram + conv feature extractor is
a stub: input_specs() provides precomputed frame embeddings (1500, 512).
NanoAdapter-I attaches to the frame embeddings (encoder side), NanoAdapter-T
to the decoder token embeddings — the enc-dec instantiation of NanoEdge.

Decode shapes use the decoder with positions extended past 448 (backbone
stand-in semantics, see DESIGN.md §4). long_500k is skipped (fixed encoder
context; full cross+self attention).

Sharding note: 8 heads % 16 != 0 -> attention replicated on model axis;
d_ff=2048 % 16 == 0 carries the tensor parallelism. vocab 51865 odd ->
embedding replicated.
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,           # decoder layers
        n_enc_layers=6,
        enc_seq_len=1500,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        max_seq_len=32768,
        pos_type="learned",
        norm="layernorm",
        act="gelu",
        frontend_dim=512,
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text", "image")),
    )
