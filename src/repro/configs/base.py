"""Configuration system.

``ModelConfig`` fully describes a backbone (any of the 6 assigned families) +
its NanoEdge adaptation. ``InputShape`` describes a workload. The registry in
``repro.configs`` maps ``--arch`` ids to config builders.

All assigned-architecture configs cite their source in the module docstring of
their own file under ``repro/configs/``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight (frozen backbone -> reported only)
    shared_d_ff: int = 0  # llama4-style shared expert FFN width (0 = none)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD — state space duality, arXiv:2405.21060)."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64       # SSD multi-head: d_inner / head_dim heads
    chunk_size: int = 256    # chunked-scan block length (TPU MXU-friendly duality form)
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (Griffin/RecurrentGemma, arXiv:2402.19427)."""

    d_rnn: int = 0            # recurrence width (0 -> d_model)
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")  # 1:2 attn:recurrent
    local_window: int = 2048  # local-attention window of the attn layers


@dataclass(frozen=True)
class AdapterConfig:
    """NanoEdge / NanoAdapter configuration (the paper's contribution)."""

    rank: int = 64
    alpha: float = 128.0
    modalities: Tuple[str, ...] = ("text",)  # ("text",), or ("text", "image")
    dropout: float = 0.0
    dtype: str = "float32"   # adapters train in fp32 (tiny), backbone runs bf16


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024
    max_seq_len: int = 8192

    # attention / positions
    pos_type: str = "rope"         # rope | mrope | learned | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA window (h2o-danube: 4096)
    logit_softcap: float = 0.0             # grok-style attn-logit soft cap (0 = off)

    # block structure
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | geglu | gelu
    tie_embeddings: bool = False
    parallel_block: bool = False   # parallel attn+ffn residual (grok-style off; kept for ext.)

    # sub-family configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # encoder-decoder (audio family, whisper-style)
    n_enc_layers: int = 0
    enc_seq_len: int = 1500        # fixed encoder memory length (frames)

    # modality frontend stub (vlm/audio): incoming embedding width before connector
    frontend_dim: int = 0          # 0 -> no image/audio stream

    # NanoEdge
    adapter: AdapterConfig = field(default_factory=AdapterConfig)

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True             # activation-checkpoint the scanned layer body
    scan_layers: bool = True       # lax.scan over stacked layer params
    use_pallas: bool = False       # route hot ops through Pallas kernels (TPU)
    attn_chunk: Optional[int] = None   # blockwise-softmax query chunking (jnp path);
                                       # bounds live logits to (B, H, chunk, S)
    loss_chunk: Optional[int] = None   # blockwise cross-entropy (bounds (B, chunk, V) logits)
    seq_parallel: bool = False         # Megatron-SP: residual stream sequence-sharded
                                       # over the model axis; AR -> AG/RS pairs (dense/vlm/moe)
    ctx_parallel_attn: bool = False    # shard QUERY sequence over model when heads don't
                                       # divide the axis (prefill-only: the bwd pass of this
                                       # layout regresses — EXPERIMENTS §Perf qwen1.5)

    # sub-quadratic marker (decides long_500k eligibility)
    @property
    def subquadratic(self) -> bool:
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 layers, d_model ≤ 512, ≤4 experts.

    Keeps every structural switch (family, pos_type, bias, window, pattern)
    identical so the smoke test exercises the same code path as the full config.
    """
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # preserve the GQA grouping ratio where possible
    if cfg.n_kv_heads < cfg.n_heads:
        n_kv = max(1, n_heads // max(1, cfg.q_per_kv))
    head_dim = d_model // n_heads
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=min(cfg.max_seq_len, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        mrope_sections=(head_dim // 4, head_dim // 8, head_dim // 8) if cfg.mrope_sections else (),
        dtype="float32",
        remat=False,
        adapter=dataclasses.replace(cfg.adapter, rank=4, alpha=8.0),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4), top_k=min(cfg.moe.top_k, 2),
            shared_d_ff=min(cfg.moe.shared_d_ff, 256) if cfg.moe.shared_d_ff else 0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk_size=32)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(
            cfg.rglru, d_rnn=0, local_window=min(cfg.rglru.local_window, 64)
        )
        kw["n_layers"] = 3  # one full (rec, rec, attn) block
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = min(cfg.n_enc_layers, 2)
        kw["enc_seq_len"] = min(cfg.enc_seq_len, 64)
    if cfg.frontend_dim:
        kw["frontend_dim"] = min(cfg.frontend_dim, 128)
    kw.update(overrides)
    return replace(cfg, **kw)


@dataclass(frozen=True)
class InputShape:
    """A workload: (kind, seq_len, global_batch)."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}
