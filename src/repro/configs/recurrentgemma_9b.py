"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427 (Griffin) / RecurrentGemma report] 38L, d_model=4096,
16 heads (GQA kv=1 == MQA), d_ff=12288, vocab=256000, RG-LRU recurrence
width 4096, local-attention window 2048, block pattern (rec, rec, attn).

38 layers = 12 full (rec, rec, attn) triples + 2 trailing recurrent layers;
the layer stack scans the 12 triples and runs the 2 extras as a second scan
(see repro.models.transformer).
"""
from repro.configs.base import AdapterConfig, ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        max_seq_len=8192,
        pos_type="rope",
        rope_theta=10000.0,
        norm="rmsnorm",
        act="geglu",
        rglru=RGLRUConfig(d_rnn=4096, conv_width=4, local_window=2048),
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
