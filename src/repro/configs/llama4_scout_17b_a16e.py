"""llama4-scout-17b-a16e — MoE decoder, 16 experts top-1, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L, d_model=5120, 40 heads (GQA kv=8),
d_ff=8192 per expert, vocab=202048, MoE 16e top-1 with a shared expert
(llama4 routes top-1 + always-on shared FFN).
"""
from repro.configs.base import AdapterConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        max_seq_len=32768,
        pos_type="rope",
        rope_theta=500000.0,
        norm="rmsnorm",
        act="swiglu",
        moe=MoEConfig(n_experts=16, top_k=1, capacity_factor=1.25, shared_d_ff=8192),
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
