"""internlm2-20b — dense decoder, GQA.

[arXiv:2403.17297] 48L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384,
vocab=92544, RoPE theta 1e6 (long-context variant).
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        max_seq_len=32768,
        pos_type="rope",
        rope_theta=1000000.0,
        norm="rmsnorm",
        act="swiglu",
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
