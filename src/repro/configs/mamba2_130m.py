"""mamba2-130m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 24L, d_model=768, d_ff=0 (Mamba2 block replaces both
mixer and MLP), vocab=50280, ssm_state=128, expand=2 (d_inner=1536),
SSD head_dim=64 (24 SSD heads), conv width 4.

TPU adaptation: the CUDA selective-scan is replaced by the chunked-matmul
SSD form (intra-chunk quadratic term on the MXU + inter-chunk recurrence),
implemented as a Pallas kernel in repro.kernels.ssd_scan.

Sharding note: vocab 50280 % 16 != 0 -> embedding replicated (77 MB bf16).
"""
from repro.configs.base import AdapterConfig, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=24,          # SSD heads = d_inner / head_dim
        n_kv_heads=24,
        d_ff=0,
        vocab_size=50280,
        max_seq_len=1048576,
        pos_type="none",
        norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
