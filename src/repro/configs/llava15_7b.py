"""llava-1.5-7b — the paper's primary backbone (LLaVA-1.5 on Vicuna-7B).

[Liu et al. 2024b; paper Tab. 1/2] 32L, d_model=4096, 32 heads (MHA),
d_ff=11008, vocab=32000, CLIP ViT-L/14-336 vision frontend (stubbed,
patch-embedding width 1024) + 2-layer MLP connector.

This config is used for the exact Tab. 1 reproduction:
  client params  = vision encoder (~303.5M) + connector + NanoAdapters
  server uploads = 2 × rank-64 NanoAdapters ≈ 1.05M params.
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-1.5-7b",
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        max_seq_len=4096,
        pos_type="rope",
        rope_theta=10000.0,
        norm="rmsnorm",
        act="swiglu",
        frontend_dim=1024,
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text", "image")),
    )
