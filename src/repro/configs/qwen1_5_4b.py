"""qwen1.5-4b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family scaled per assignment] 40L, d_model=2560,
20 heads (GQA kv=20 — i.e. MHA), d_ff=6912, vocab=151936, QKV bias.

Sharding note: 20 heads % 16-way model axis != 0 -> attention projections are
replicated over the model axis; FFN (6912 % 16 == 0) carries tensor
parallelism (see DESIGN.md §5).
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_ff=6912,
        vocab_size=151936,
        max_seq_len=32768,
        pos_type="rope",
        rope_theta=1000000.0,
        qkv_bias=True,
        norm="rmsnorm",
        act="swiglu",
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
