"""glm4-9b — dense decoder, RoPE + GQA kv=2.

[hf:THUDM/glm-4-9b] 40L, d_model=4096, 32 heads (GQA kv=2), d_ff=13696,
vocab=151552. GLM uses partial rotary (applied to half the head dim) and
QKV bias on glm-4; we model the QKV bias and standard full RoPE (partial
rotary is a numerics detail orthogonal to the systems contribution).

Sharding note: kv=2 % 16 != 0 -> KV projections/cache replicated over the
model axis, Q sharded on its 32 heads.
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        max_seq_len=131072,
        pos_type="rope",
        rope_theta=10000.0,
        qkv_bias=True,
        norm="rmsnorm",
        act="swiglu",
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
