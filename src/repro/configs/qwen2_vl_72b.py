"""qwen2-vl-72b — VLM decoder with M-RoPE and dynamic-resolution vision input.

[arXiv:2409.12191] 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568,
vocab=152064, M-RoPE sections (t=16, h=24, w=24) over head_dim=128,
QKV bias (qwen2 family). Vision frontend (ViT + merger) is a STUB per the
assignment carve-out: input_specs() supplies patch embeddings of width 1280
(the real ViT output dim) which the connector projects to d_model.

This is the paper's own setting (both NanoAdapter-I and NanoAdapter-T).
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        max_seq_len=32768,
        pos_type="mrope",
        rope_theta=1000000.0,
        mrope_sections=(16, 24, 24),
        qkv_bias=True,
        norm="rmsnorm",
        act="swiglu",
        frontend_dim=1280,
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text", "image")),
    )
