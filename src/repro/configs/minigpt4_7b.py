"""minigpt4-7b — the paper's second backbone (MiniGPT-4 on Vicuna-7B).

[Zhu et al. 2023] Vicuna-7B LLM (32L, d_model=4096, MHA, d_ff=11008,
vocab=32000) + EVA-CLIP ViT-G/14 + Q-Former frontend (stubbed; Q-Former
emits 32 query embeddings of width 768) + linear connector.
"""
from repro.configs.base import AdapterConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minigpt4-7b",
        family="vlm",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=32000,
        max_seq_len=4096,
        pos_type="rope",
        rope_theta=10000.0,
        norm="rmsnorm",
        act="swiglu",
        frontend_dim=768,
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text", "image")),
    )
