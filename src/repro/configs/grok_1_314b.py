"""grok-1-314b — MoE decoder, 8 experts top-2.

[hf:xai-org/grok-1] 64L, d_model=6144, 48 heads (GQA kv=8), d_ff=32768 per
expert, vocab=131072, MoE 8e top-2, attention-logit softcap 30 (grok uses
tanh soft-capping on attention logits).

The flagship server-centralization case for FedNano: 314B params (~628 GB
bf16) can never be deployed client-side; with FedNano the clients hold only
NanoEdge (<5%) and upload rank-64 adapters (~0.01%).
"""
from repro.configs.base import AdapterConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        max_seq_len=8192,
        pos_type="rope",
        rope_theta=10000.0,
        logit_softcap=30.0,
        norm="rmsnorm",
        act="gelu",
        moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
        adapter=AdapterConfig(rank=64, alpha=128.0, modalities=("text",)),
    )
