"""Composable client→server upload transforms (the wire pipeline).

Any strategy can chain these on the upload path: each transform receives the
candidate upload θ and the global reference, returns the (possibly lossy)
θ the server will actually see, its own carried state (e.g. an error-
feedback residual), and the bytes that would cross the wire — which the
engine folds into ``CommLog`` as ``param_up_wire``.

    theta, state, wire = transform.apply(ctx, theta, global_ref, state)

``wire=None`` means "size unchanged" (e.g. clip+noise). Transforms are
frozen dataclasses (hashable, value-equal); per-client state is threaded by
the engine, so one transform instance serves every client.

Wire format
-----------
Every payload is **self-describing**: ``encode`` produces a
:class:`WireMessage` stamped with ``(codec, version)`` and the exact byte
count the encoding occupies on the wire, and ``decode_wire`` dispatches on
the stamp — rejecting unknown codecs and versions instead of guessing.
``apply`` is implemented as encode→decode, so ``param_up_wire`` accounting
is by construction the size of the message that actually crossed, and the
accounting survives format evolution: bump ``WIRE_FORMAT_VERSION`` when an
encoding changes and old readers fail loudly.

Transforms that carry per-client state across rounds (error-feedback
residuals) also expose ``state_template(global_ref)`` — the reference
structure checkpoint/resume restores the state into. A transform with
persistent state but no template cannot ride through a ``RunState``
checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Version of every codec's on-the-wire encoding. Bump when any payload
# layout changes; decode_wire rejects messages from other versions.
WIRE_FORMAT_VERSION = 1


class TransformCtx(NamedTuple):
    """Where in the protocol the transform is running."""

    cid: int
    round_idx: int


class WireMessage(NamedTuple):
    """A self-describing upload payload.

    ``nbytes`` is what CommLog records as ``param_up_wire`` — tests pin that
    it equals the encoded payload exactly. ``payload`` is codec-specific
    (pytrees of arrays); ``decode_wire`` reconstructs the θ the server sees.
    """

    codec: str
    version: int
    payload: Any
    nbytes: int


_DECODERS: Dict[str, Callable] = {}


def _codec(name: str):
    """Register ``fn(msg, global_ref) -> theta`` as the decoder for a codec."""

    def deco(fn):
        _DECODERS[name] = fn
        return fn

    return deco


def decode_wire(msg: WireMessage, global_ref):
    """Server-side decode: dispatch on the (codec, version) stamp.

    Unknown stamps are protocol errors, never silent fallbacks — a server
    one format behind must refuse the upload rather than mis-reconstruct it.
    """
    if msg.version != WIRE_FORMAT_VERSION:
        raise ValueError(
            f"wire message {msg.codec!r} has format version {msg.version}, "
            f"this code speaks v{WIRE_FORMAT_VERSION}; refusing to decode")
    dec = _DECODERS.get(msg.codec)
    if dec is None:
        raise ValueError(
            f"unknown wire codec {msg.codec!r}; known: "
            f"{', '.join(sorted(_DECODERS))}")
    return dec(msg, global_ref)


@_codec("identity")
@_codec("dp_fp32")
def _decode_dense(msg, global_ref):
    # dense fp32 tree: the payload IS the upload
    return msg.payload


@_codec("int8_ef")
def _decode_int8(msg, global_ref):
    from repro.core.compression import dequantize_delta, QuantizedDelta
    from repro.utils import tree_add

    q = QuantizedDelta(payload=msg.payload["q"], scales=msg.payload["scales"],
                       base_bytes=0, wire_bytes=msg.nbytes)
    return tree_add(global_ref, dequantize_delta(q))


def _scatter_topk(ref_leaf, packed):
    vals, idx = packed["vals"], packed["idx"]
    flat = jnp.zeros((ref_leaf.size,), ref_leaf.dtype).at[idx].set(vals)
    return flat.reshape(ref_leaf.shape)


@_codec("topk")
def _decode_topk(msg, global_ref):
    from repro.utils import tree_add

    # global_ref's treedef bounds the map, so each packed {vals, idx} dict
    # arrives whole at its leaf position
    sparse = jax.tree.map(_scatter_topk, global_ref, msg.payload)
    return tree_add(global_ref, sparse)


@dataclass(frozen=True)
class UpdateTransform:
    """Identity transform; subclass and override ``encode`` (and, for
    transforms whose wire size differs from the dense tree, set
    ``wire_transparent = False`` so ``apply`` reports the encoded size)."""

    # True => apply() reports wire=None ("size unchanged"): the engine falls
    # back to the dense tree size, and a later size-changing transform in
    # the chain may still override it. Size-changing codecs set False.
    wire_transparent = True

    def encode(self, ctx: TransformCtx, theta, global_ref,
               state) -> Tuple[WireMessage, Any]:
        from repro.utils import tree_bytes

        msg = WireMessage(codec="identity", version=WIRE_FORMAT_VERSION,
                          payload=theta, nbytes=tree_bytes(theta))
        return msg, state

    def state_template(self, global_ref):
        """Reference structure for this transform's carried per-client state
        (None = stateless; checkpoint/resume then has nothing to restore)."""
        return None

    def apply(self, ctx: TransformCtx, theta, global_ref, state):
        msg, state = self.encode(ctx, theta, global_ref, state)
        theta = decode_wire(msg, global_ref)
        return theta, state, (None if self.wire_transparent else msg.nbytes)


@dataclass(frozen=True)
class ClipNoiseDP(UpdateTransform):
    """Client-level DP: L2-clip the delta to ``clip_norm``, add Gaussian
    noise ``noise_mult·clip_norm`` (McMahan et al. 2018). Wire size unchanged."""

    clip_norm: float = 1.0
    noise_mult: float = 0.0

    def encode(self, ctx, theta, global_ref, state):
        from repro.core.privacy import privatize_update
        from repro.utils import tree_bytes

        # deterministic per-(client, round) noise stream, independent of the
        # training PRNG so DP on/off never perturbs the learning trajectory
        key = jax.random.fold_in(jax.random.PRNGKey(1234 + ctx.cid), ctx.round_idx)
        theta, _ = privatize_update(
            key, theta, global_ref,
            clip_norm=self.clip_norm, noise_mult=self.noise_mult,
        )
        msg = WireMessage(codec="dp_fp32", version=WIRE_FORMAT_VERSION,
                          payload=theta, nbytes=tree_bytes(theta))
        return msg, state


@dataclass(frozen=True)
class Int8EFQuant(UpdateTransform):
    """int8 delta quantization with error feedback (≈4× smaller uploads);
    the residual is carried in ``state`` and folded into the next round."""

    wire_transparent = False

    def encode(self, ctx, theta, global_ref, state):
        from repro.core.compression import compress_update, init_error_feedback

        err = state if state is not None else init_error_feedback(theta)
        q, err, _ = compress_update(theta, global_ref, err)
        msg = WireMessage(codec="int8_ef", version=WIRE_FORMAT_VERSION,
                          payload={"q": q.payload, "scales": q.scales},
                          nbytes=q.wire_bytes)
        return msg, err

    def state_template(self, global_ref):
        from repro.core.compression import init_error_feedback

        return init_error_feedback(global_ref)


@dataclass(frozen=True)
class TopKSparsify(UpdateTransform):
    """Keep only the top ``frac`` largest-magnitude delta entries per leaf,
    with error feedback; wire = kept values + int32 indices."""

    frac: float = 0.1
    wire_transparent = False

    def encode(self, ctx, theta, global_ref, state):
        from repro.utils import tree_sub, tree_add

        delta = tree_sub(theta, global_ref)
        if state is not None:
            delta = tree_add(delta, state)

        wire = 0

        def keep(x):
            nonlocal wire
            k = max(1, int(round(self.frac * x.size)))
            wire += k * (x.dtype.itemsize + 4)
            # index-based selection: exactly k entries survive even under
            # ties (a threshold compare would keep extras and falsify wire)
            flat = x.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            idx = idx.astype(jnp.int32)
            return {"vals": flat[idx], "idx": idx}

        packed = jax.tree.map(keep, delta)
        msg = WireMessage(codec="topk", version=WIRE_FORMAT_VERSION,
                          payload=packed, nbytes=wire)
        # error feedback: exactly what the sparse reconstruction drops (the
        # scatter here is the same computation decode_wire performs)
        sparse = jax.tree.map(_scatter_topk, delta, packed)
        err = tree_sub(delta, sparse)
        return msg, err

    def state_template(self, global_ref):
        from repro.utils import tree_zeros_like

        return tree_zeros_like(global_ref)


def default_transforms(hp) -> Tuple[UpdateTransform, ...]:
    """The legacy ``HyperParams``-driven chain: DP first, then int8+EF —
    byte-for-byte what the pre-plugin engine spliced inline."""
    chain = []
    if hp.dp_clip > 0.0:
        chain.append(ClipNoiseDP(clip_norm=hp.dp_clip, noise_mult=hp.dp_noise))
    if hp.compress_uploads:
        chain.append(Int8EFQuant())
    return tuple(chain)
