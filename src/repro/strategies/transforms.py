"""Composable client→server upload transforms (the wire pipeline).

Any strategy can chain these on the upload path: each transform receives the
candidate upload θ and the global reference, returns the (possibly lossy)
θ the server will actually see, its own carried state (e.g. an error-
feedback residual), and the bytes that would cross the wire — which the
engine folds into ``CommLog`` as ``param_up_wire``.

    theta, state, wire = transform.apply(ctx, theta, global_ref, state)

``wire=None`` means "size unchanged" (e.g. clip+noise). Transforms are
frozen dataclasses (hashable, value-equal); per-client state is threaded by
the engine, so one transform instance serves every client.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class TransformCtx(NamedTuple):
    """Where in the protocol the transform is running."""

    cid: int
    round_idx: int


@dataclass(frozen=True)
class UpdateTransform:
    """Identity transform; subclass and override ``apply``."""

    def apply(self, ctx: TransformCtx, theta, global_ref, state):
        return theta, state, None


@dataclass(frozen=True)
class ClipNoiseDP(UpdateTransform):
    """Client-level DP: L2-clip the delta to ``clip_norm``, add Gaussian
    noise ``noise_mult·clip_norm`` (McMahan et al. 2018). Wire size unchanged."""

    clip_norm: float = 1.0
    noise_mult: float = 0.0

    def apply(self, ctx, theta, global_ref, state):
        from repro.core.privacy import privatize_update

        # deterministic per-(client, round) noise stream, independent of the
        # training PRNG so DP on/off never perturbs the learning trajectory
        key = jax.random.fold_in(jax.random.PRNGKey(1234 + ctx.cid), ctx.round_idx)
        theta, _ = privatize_update(
            key, theta, global_ref,
            clip_norm=self.clip_norm, noise_mult=self.noise_mult,
        )
        return theta, state, None


@dataclass(frozen=True)
class Int8EFQuant(UpdateTransform):
    """int8 delta quantization with error feedback (≈4× smaller uploads);
    the residual is carried in ``state`` and folded into the next round."""

    def apply(self, ctx, theta, global_ref, state):
        from repro.core.compression import compress_update, init_error_feedback
        from repro.utils import tree_add

        err = state if state is not None else init_error_feedback(theta)
        q, err, recon = compress_update(theta, global_ref, err)
        return tree_add(global_ref, recon), err, q.wire_bytes


@dataclass(frozen=True)
class TopKSparsify(UpdateTransform):
    """Keep only the top ``frac`` largest-magnitude delta entries per leaf,
    with error feedback; wire = kept values + int32 indices."""

    frac: float = 0.1

    def apply(self, ctx, theta, global_ref, state):
        from repro.utils import tree_add, tree_sub

        delta = tree_sub(theta, global_ref)
        if state is not None:
            delta = tree_add(delta, state)

        wire = 0

        def keep(x):
            nonlocal wire
            k = max(1, int(round(self.frac * x.size)))
            wire += k * (x.dtype.itemsize + 4)
            # index-based mask: exactly k entries survive even under ties
            # (a threshold compare would keep extras and falsify `wire`)
            flat = x.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
            return jnp.where(mask, flat, jnp.zeros_like(flat)).reshape(x.shape)

        sparse = jax.tree.map(keep, delta)
        err = tree_sub(delta, sparse)
        return tree_add(global_ref, sparse), err, wire


def default_transforms(hp) -> Tuple[UpdateTransform, ...]:
    """The legacy ``HyperParams``-driven chain: DP first, then int8+EF —
    byte-for-byte what the pre-plugin engine spliced inline."""
    chain = []
    if hp.dp_clip > 0.0:
        chain.append(ClipNoiseDP(clip_norm=hp.dp_clip, noise_mult=hp.dp_noise))
    if hp.compress_uploads:
        chain.append(Int8EFQuant())
    return tuple(chain)
