"""Strategy-plugin API: registry-backed federated methods.

A federated method = a ``Strategy`` (client objective + aggregation + eval
choice) ⊕ a chain of ``UpdateTransform``s on the upload wire ⊕ an optional
``ServerOpt`` ⊕ a ``ClientSampler``. The engine loop in
``repro.core.federated`` is fixed; new methods are plugins:

    from repro.strategies import Strategy, register

    @register("my_method")
    class MyMethod(Strategy):
        def wrap_local_loss(self, loss_fn, hp, global_ref):
            ...

    run_federated(key, cfg, train, evald, strategy="my_method")

See README.md "Writing a custom strategy" for a worked example.
"""
from repro.strategies.base import (
    Strategy,
    available_strategies,
    get_strategy,
    register,
)
from repro.strategies.builtin import (
    FedAdam,
    FedAvg,
    FedAvgM,
    FedDPAF,
    FedNano,
    FedNanoEF,
    FedProx,
    LocFT,
)
from repro.strategies.sampling import (
    ClientSampler,
    FixedSizeSampler,
    UniformSampler,
    round_key,
)
from repro.strategies.server_opt import FedAdamOpt, FedAvgMOpt, FedBuffOpt, ServerOpt
from repro.strategies.transforms import (
    WIRE_FORMAT_VERSION,
    ClipNoiseDP,
    Int8EFQuant,
    TopKSparsify,
    TransformCtx,
    UpdateTransform,
    WireMessage,
    decode_wire,
    default_transforms,
)

__all__ = [
    "Strategy",
    "available_strategies",
    "get_strategy",
    "register",
    "FedAdam",
    "FedAvg",
    "FedAvgM",
    "FedDPAF",
    "FedNano",
    "FedNanoEF",
    "FedProx",
    "LocFT",
    "ClientSampler",
    "FixedSizeSampler",
    "UniformSampler",
    "round_key",
    "FedAdamOpt",
    "FedAvgMOpt",
    "FedBuffOpt",
    "ServerOpt",
    "WIRE_FORMAT_VERSION",
    "ClipNoiseDP",
    "Int8EFQuant",
    "TopKSparsify",
    "TransformCtx",
    "UpdateTransform",
    "WireMessage",
    "decode_wire",
    "default_transforms",
]
