"""The six paper strategies as registry plugins, plus server-opt variants.

Each class is the strategy column of paper Tab. 2 expressed through the
``Strategy`` hooks — no engine changes, no if/elif chains. The seeded
numerics match the pre-plugin string-dispatch implementation exactly
(tests/golden/strategy_parity.json).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.strategies.base import Strategy, register


def _fisher_fold_tree(num, den, theta, fisher, w, *, use_pallas=False):
    """Fold one client's (θ, F, w) into the running f32 num/den trees.

    The jitted jnp path fuses the fold into one elementwise pass per leaf;
    ``use_pallas`` routes each leaf through the fused ``fisher_fold`` Pallas
    kernel instead (interpret mode off-TPU, same numerics)."""
    if use_pallas:
        from repro.kernels.fisher_merge import ops as fm_ops

        folded = jax.tree.map(
            lambda nm, dn, t, f: fm_ops.fisher_fold(nm, dn, t, f, w,
                                                    interpret=True),
            num, den, theta, fisher)
    else:
        folded = _fisher_fold_tree_jit(num, den, theta, fisher, w)
    new_num = jax.tree.map(lambda p: p[0], folded,
                           is_leaf=lambda p: isinstance(p, tuple))
    new_den = jax.tree.map(lambda p: p[1], folded,
                           is_leaf=lambda p: isinstance(p, tuple))
    return new_num, new_den


@jax.jit
def _fisher_fold_tree_jit(num, den, theta, fisher, w):
    return jax.tree.map(
        lambda nm, dn, t, f: (
            nm + w * f.astype(jnp.float32) * t.astype(jnp.float32),
            dn + w * f.astype(jnp.float32)),
        num, den, theta, fisher)


@jax.jit
def _fisher_fold_stacks_jit(theta_stacks, fisher_stacks, ws):
    """Σ over stacked ``(K, ...)`` chunks of (Σ wFθ, Σ wF) in one dispatch:
    the client-axis reductions run where the stacks live (sharded over the
    mesh under the sharded engine), so no per-client tree ever reaches the
    host — and fusing all chunks into one call pays the cross-device
    reduction barrier once per round instead of once per chunk."""
    num = den = None
    for t, f, w in zip(theta_stacks, fisher_stacks, ws):
        n = jax.tree.map(
            lambda tt, ff, w=w: jnp.tensordot(
                w, ff.astype(jnp.float32) * tt.astype(jnp.float32), axes=1),
            t, f)
        d = jax.tree.map(
            lambda ff, w=w: jnp.tensordot(w, ff.astype(jnp.float32), axes=1),
            f)
        num = n if num is None else jax.tree.map(jnp.add, num, n)
        den = d if den is None else jax.tree.map(jnp.add, den, d)
    return num, den


@register("fedavg")
@dataclass(frozen=True)
class FedAvg(Strategy):
    """Data-size-weighted parameter averaging (McMahan et al. 2017)."""


@register("fedprox")
@dataclass(frozen=True)
class FedProx(FedAvg):
    """FedAvg + (μ/2)·‖θ − θ_global‖² proximal term in the local loss."""

    def wrap_local_loss(self, loss_fn, hp, global_ref):
        from repro.utils import tree_sq_norm, tree_sub

        def wrapped(adp):
            loss, aux = loss_fn(adp)
            loss = loss + 0.5 * hp.prox_mu * tree_sq_norm(tree_sub(adp, global_ref))
            return loss, aux

        return wrapped


@register("fednano")
@dataclass(frozen=True)
class FedNano(Strategy):
    """The paper's method: dedicated diagonal-FIM pass + Fisher merge."""

    wants_fisher: Optional[str] = "dedicated"

    def aggregate(self, thetas, fishers, data_sizes, *, use_pallas=False):
        from repro.core import aggregation

        return aggregation.fisher_merge(
            thetas, fishers, data_sizes, use_pallas=use_pallas
        )

    # streaming Fisher merge: fold Σ wFθ / Σ wF ONE CLIENT AT A TIME into
    # running f32 sums — no (K, ...) stack ever exists, so server memory is
    # O(1) in the client count (the chunked/buffered engines hand us their
    # buffered uploads; we still never stack them). finalize reproduces
    # Eq. 1 with the eps floor scaled by the total weight
    # (num/(den+eps·W) == (num/W)/((den/W)+eps), the batch formula).
    def agg_stream_fold(self, acc, thetas, fishers, weights, *, use_pallas=False):
        if fishers is None or any(f is None for f in fishers):
            raise ValueError("fednano streaming merge needs a FIM per upload")
        if acc is None:
            like = jax.tree.map(lambda x: x.dtype, thetas[0])
            acc = {"num": jax.tree.map(
                       lambda x: jnp.zeros(x.shape, jnp.float32), thetas[0]),
                   "den": jax.tree.map(
                       lambda x: jnp.zeros(x.shape, jnp.float32), thetas[0]),
                   "w": 0.0, "like": like}
        num, den = acc["num"], acc["den"]
        for theta, fisher, w in zip(thetas, fishers, weights):
            num, den = _fisher_fold_tree(num, den, theta, fisher,
                                         jnp.float32(w), use_pallas=use_pallas)
        return {"num": num, "den": den,
                "w": acc["w"] + float(sum(float(w) for w in weights)),
                "like": acc["like"]}

    def agg_stream_fold_stacked(self, acc, theta_stack, fisher_stack,
                                weights, *, use_pallas=False):
        if not isinstance(theta_stack, (list, tuple)):
            theta_stack = [theta_stack]
            fisher_stack = [fisher_stack]
            weights = [weights]
        if fisher_stack is None or any(f is None for f in fisher_stack):
            raise ValueError("fednano streaming merge needs a FIM per upload")
        ws = tuple(jnp.asarray(list(w), jnp.float32) for w in weights)
        num, den = _fisher_fold_stacks_jit(
            tuple(theta_stack), tuple(fisher_stack), ws)
        wsum = float(sum(float(x) for w in weights for x in w))
        if acc is None:
            return {"num": num, "den": den, "w": wsum,
                    "like": jax.tree.map(lambda x: x.dtype, theta_stack[0])}
        from repro.utils import tree_add

        return {"num": tree_add(acc["num"], num),
                "den": tree_add(acc["den"], den),
                "w": acc["w"] + wsum, "like": acc["like"]}

    def agg_stream_finalize(self, acc, *, use_pallas=False, eps: float = 1e-8):
        if acc is None:
            return None
        floor = eps * acc["w"]
        return jax.tree.map(
            lambda n, d, t: (n / (d + floor)).astype(t),
            acc["num"], acc["den"], acc["like"])


@register("fednano_ef")
@dataclass(frozen=True)
class FedNanoEF(FedNano):
    """FedNano with the FIM accumulated from training-step grads (Tab. 7)."""

    wants_fisher: Optional[str] = "streaming"


@register("feddpa_f")
@dataclass(frozen=True)
class FedDPAF(FedAvg):
    """Dual adapters: fedavg the shared one, keep a frozen personal one
    trained in the warmup round(s) only."""

    dual_adapters = True

    def local_warmup(self, rounds_participated, hp):
        return rounds_participated < hp.dpa_warmup_rounds

    def eval_params(self, global_adapters, client):
        return global_adapters, client.local_adapters


@register("locft")
@dataclass(frozen=True)
class LocFT(Strategy):
    """Local-only fine-tuning: no upload, no download after round 0."""

    aggregates = False

    def downloads_global(self, rounds_participated):
        return rounds_participated == 0

    def aggregate(self, thetas, fishers, data_sizes, *, use_pallas=False):
        return None

    def eval_params(self, global_adapters, client):
        return client.adapters, None


@register("fedavgm")
@dataclass(frozen=True)
class FedAvgM(FedAvg):
    """FedAvg + server momentum on the round pseudo-gradient (Hsu et al.)."""

    server_lr: float = 1.0
    beta: float = 0.9

    def server_opt(self):
        from repro.strategies.server_opt import FedAvgMOpt

        return FedAvgMOpt(lr=self.server_lr, beta=self.beta)


@register("fedadam")
@dataclass(frozen=True)
class FedAdam(FedAvg):
    """FedAvg + adaptive Adam server step (FedOpt, Reddi et al. 2021)."""

    server_lr: float = 0.1
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3

    def server_opt(self):
        from repro.strategies.server_opt import FedAdamOpt

        return FedAdamOpt(lr=self.server_lr, b1=self.b1, b2=self.b2, eps=self.eps)
