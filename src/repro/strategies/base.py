"""Strategy plugin API: the hooks a federated method implements.

The engine loop in ``repro.core.federated`` is method-agnostic; everything
that distinguishes FedNano from FedAvg from LocFT lives in a ``Strategy``
subclass wired in through six hooks:

    init_client        build the per-client state (dual adapters, opt state)
    wrap_local_loss    modify the local objective (e.g. FedProx prox term)
    wants_fisher       None | "dedicated" | "streaming" FIM estimation
    post_local_update  choose what the client uploads after local steps
    aggregate          merge client uploads into the new global adapters
    eval_params        which (shared, personal) params a client evaluates

plus three small scheduling predicates (``downloads_global``,
``local_warmup``, ``aggregates``), an optional ``server_opt`` factory, and
the streaming-aggregation triple (``agg_stream_init`` / ``agg_stream_fold``
/ ``agg_stream_finalize``) the chunked/buffered engines fold uploads through
so server memory stays O(chunk) rather than O(cohort).

Strategies are **frozen dataclasses**: hashable and value-equal, so jitted
train steps are compiled once per (cfg, strategy, hp) triple and shared
across clients. Register with ``@register("name")``; resolve names (or pass
instances straight through) with ``get_strategy``.

NOTE: this module must not import ``repro.core`` at module scope — the
engine imports us, so core imports here stay inside methods.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Type, Union

import jax

_REGISTRY: Dict[str, Type["Strategy"]] = {}


@jax.jit
def _weighted_sum_stacks_jit(theta_stacks, ws):
    """Σ over chunks of (w_chunk · θ_chunk) in one dispatch — chunk count
    and widths are constant within a run, so the trace caches across
    rounds."""
    import jax.numpy as jnp

    num = None
    for t, w in zip(theta_stacks, ws):
        contrib = jax.tree.map(
            lambda s, w=w: jnp.tensordot(w, s.astype(jnp.float32), axes=1), t)
        num = contrib if num is None else jax.tree.map(jnp.add, num, contrib)
    return num


def register(name: str) -> Callable[[Type["Strategy"]], Type["Strategy"]]:
    """Class decorator: ``@register("fednano")`` adds the class to the
    registry and stamps ``cls.name`` so results/logs carry the public name."""

    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_strategies() -> Tuple[str, ...]:
    """Sorted names of every registered strategy."""
    import repro.strategies.builtin  # noqa: F401  (ensure built-ins register)

    return tuple(sorted(_REGISTRY))


def get_strategy(spec: Union[str, "Strategy"]) -> "Strategy":
    """Resolve a strategy name (or pass an instance through).

    Unknown names raise ``ValueError`` listing the registered strategies so
    CLI typos are self-explanatory.
    """
    if isinstance(spec, Strategy):
        return spec
    if isinstance(spec, str):
        import repro.strategies.builtin  # noqa: F401  (ensure built-ins register)

        cls = _REGISTRY.get(spec)
        if cls is None:
            raise ValueError(
                f"unknown strategy {spec!r}; registered strategies: "
                f"{', '.join(sorted(_REGISTRY))}"
            )
        return cls()
    raise TypeError(f"strategy must be a name or Strategy instance, got {type(spec)}")


@dataclass(frozen=True)
class Strategy:
    """Base strategy: FedAvg-shaped defaults, every hook overridable."""

    name = "strategy"            # overwritten by @register
    dual_adapters = False        # keep a personal adapter next to the shared one
    aggregates = True            # False => server never merges (local-only)
    wants_fisher: Optional[str] = None  # None | "dedicated" | "streaming"

    # -- client lifecycle ---------------------------------------------------
    def init_client(self, key, cfg, cid: int, n_examples: int):
        from repro.core import adapters as adapters_lib
        from repro.core.client import ClientState
        from repro.optim import adamw_init

        k1, k2 = jax.random.split(key)
        adp = adapters_lib.init_nanoedge(k1, cfg)
        local = adapters_lib.init_nanoedge(k2, cfg) if self.dual_adapters else None
        return ClientState(
            cid=cid,
            adapters=adp,
            opt_state=adamw_init(adp),
            n_examples=n_examples,
            local_adapters=local,
        )

    def init_clients(self, keys, cfg, cids, n_examples):
        """Batch-initialize a cohort. Bit-identical to per-client
        ``init_client`` calls (jax.random is counter-based, so the vmapped
        draw matches K sequential draws exactly). Strategies that override
        ``init_client`` — ragged or data-dependent state the stacked fast
        path can't express — automatically fall back to the loop."""
        if type(self).init_client is not Strategy.init_client:
            return [self.init_client(k, cfg, cid, n)
                    for k, cid, n in zip(keys, cids, n_examples)]
        from repro.core.client import init_clients_batched

        return init_clients_batched(self, keys, cfg, cids, n_examples)

    def downloads_global(self, rounds_participated: int) -> bool:
        """Whether the client adopts θ_global at the start of this round.
        ``rounds_participated`` counts the client's OWN prior rounds, so the
        schedule survives partial participation (== round index when all
        clients run every round)."""
        return True

    def local_warmup(self, rounds_participated: int, hp) -> bool:
        """Whether this round trains the personal adapter before local steps
        (same per-client counter as ``downloads_global``)."""
        return False

    # -- local objective ----------------------------------------------------
    def wrap_local_loss(self, loss_fn: Callable, hp, global_ref) -> Callable:
        """Wrap the (adapters -> (loss, aux)) objective. Called at trace time
        inside the jitted train step; keep it pure JAX."""
        return loss_fn

    # -- upload -------------------------------------------------------------
    def post_local_update(self, state, global_adapters, round_idx: int):
        """What the client hands to the upload-transform pipeline."""
        return state.adapters

    # -- server -------------------------------------------------------------
    def aggregate(
        self,
        thetas: List,
        fishers: Optional[List],
        data_sizes: Sequence[int],
        *,
        use_pallas: bool = False,
    ):
        from repro.core import aggregation

        return aggregation.fedavg(thetas, data_sizes)

    # -- streaming aggregation ----------------------------------------------
    # The O(chunk)-memory counterpart of ``aggregate``: the engine folds
    # cohort chunks (and the buffered async mode folds staleness-weighted
    # uploads) into a running accumulator, so the server never materializes
    # all K client trees at once. The base implementation is the running
    # weighted average (== fedavg up to summation order); Fisher-merging
    # strategies override all three with a numerator/denominator pair.

    def agg_stream_init(self):
        """Fresh accumulator (None = lazily shaped on the first fold)."""
        return None

    def agg_stream_fold(self, acc, thetas: List, fishers: Optional[List],
                        weights: Sequence[float], *, use_pallas: bool = False):
        """Fold one chunk of client uploads into the accumulator.

        ``weights`` are unnormalized (data sizes, possibly staleness-scaled);
        normalization happens once in ``agg_stream_finalize``.
        """
        from repro.utils import tree_add, tree_weighted_sum

        num = tree_weighted_sum(thetas, weights)
        w = float(sum(weights))
        if acc is None:
            like = jax.tree.map(lambda x: x.dtype, thetas[0])
            return {"num": num, "w": w, "like": like}
        return {"num": tree_add(acc["num"], num), "w": acc["w"] + w,
                "like": acc["like"]}

    def agg_stream_fold_stacked(self, acc, theta_stack, fisher_stack,
                                weights: Sequence[float], *,
                                use_pallas: bool = False):
        """Fold already-stacked ``(K, ...)`` chunk(s) of uploads.

        Device-side counterpart of ``agg_stream_fold``: the sharded engine
        folds its mesh-resident cohort outputs here without ever gathering
        them to the host, masking padding rows with zero weights (a
        zero-weight row contributes nothing to the sums, so padding is
        provably inert). ``theta_stack``/``fisher_stack``/``weights`` may
        each be a LIST of per-chunk values — all chunks then fold in one
        jitted dispatch, so a round pays one cross-device reduction instead
        of one per chunk (at adapter sizes the collective barrier dwarfs
        the flops). Accumulator schema is shared with ``agg_stream_fold``/
        ``agg_stream_finalize``; the fold styles differ only in f32
        summation order.
        """
        if not isinstance(theta_stack, (list, tuple)):
            theta_stack = [theta_stack]
            weights = [weights]
        import jax.numpy as jnp

        ws = tuple(jnp.asarray(list(w), jnp.float32) for w in weights)
        num = _weighted_sum_stacks_jit(tuple(theta_stack), ws)
        wsum = float(sum(float(x) for w in weights for x in w))
        if acc is None:
            like = jax.tree.map(lambda x: x.dtype, theta_stack[0])
            return {"num": num, "w": wsum, "like": like}
        from repro.utils import tree_add

        return {"num": tree_add(acc["num"], num), "w": acc["w"] + wsum,
                "like": acc["like"]}

    def agg_stream_finalize(self, acc, *, use_pallas: bool = False):
        """Normalize the accumulator into the merged adapters (or None if
        nothing was folded)."""
        if acc is None:
            return None
        inv = 1.0 / max(acc["w"], 1e-12)
        return jax.tree.map(lambda n, d: (n * inv).astype(d),
                            acc["num"], acc["like"])

    def server_opt(self):
        """Optional ServerOpt applied to the merged result (None = identity)."""
        return None

    # -- checkpointing ------------------------------------------------------
    # Strategies are frozen dataclasses with no mutable state, so a RunState
    # snapshot needs only this identity record: the streaming-merge
    # accumulators (agg_stream_*) live strictly within one round/merge and
    # are empty at every checkpoint boundary by construction. Anything a
    # strategy carries *across* rounds belongs in ClientState or a
    # transform's threaded state, both of which the checkpoint persists.

    def checkpoint_meta(self) -> Dict[str, Any]:
        """Identity recorded in RunState meta and validated on resume, so a
        checkpoint written under one method can't silently resume under
        another (e.g. a FedNano run restored as FedAvg would drop the FIM
        semantics without a single shape mismatch to catch it)."""
        return {
            "name": self.name,
            "wants_fisher": self.wants_fisher,
            "dual_adapters": self.dual_adapters,
            "aggregates": self.aggregates,
        }

    # -- evaluation ---------------------------------------------------------
    def eval_params(self, global_adapters, client) -> Tuple[Any, Optional[Any]]:
        """(shared adapters, personal adapters) this client evaluates with."""
        return global_adapters, None
