"""Partial participation: which clients run each round.

Real cross-device FL never sees every client every round; the engine asks a
``ClientSampler`` for the round's cohort. The default (full participation)
is the paper setting and consumes no randomness, so seeded runs without a
sampler are bit-identical to the legacy loop. ``UniformSampler`` draws
⌈C·K⌉ clients without replacement from its own PRNG stream (independent of
the training keys, so changing participation never reshuffles init/DP noise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax


@dataclass(frozen=True)
class ClientSampler:
    """Full participation: every client, every round."""

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        return list(cids)


@dataclass(frozen=True)
class UniformSampler(ClientSampler):
    """Sample max(1, round(frac·K)) clients uniformly without replacement."""

    frac: float = 0.5
    seed: int = 0

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        k = len(cids)
        n = min(k, max(1, int(round(self.frac * k))))
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        idx = jax.random.choice(key, k, shape=(n,), replace=False)
        return sorted(cids[int(i)] for i in idx)


@dataclass(frozen=True)
class FixedSizeSampler(ClientSampler):
    """Draw a cohort of exactly ``n`` clients per round (cross-device FL
    convention, and what the engine benchmark sweeps: cohort size is the
    knob, population size the backdrop)."""

    n: int = 1
    seed: int = 0

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        k = len(cids)
        n = min(max(1, self.n), k)
        if n == k:
            return list(cids)
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        idx = jax.random.choice(key, k, shape=(n,), replace=False)
        return sorted(cids[int(i)] for i in idx)
