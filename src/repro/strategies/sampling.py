"""Partial participation: which clients run each round.

Real cross-device FL never sees every client every round; the engine asks a
``ClientSampler`` for the round's cohort. The default (full participation)
is the paper setting and consumes no randomness, so seeded runs without a
sampler are bit-identical to the legacy loop. ``UniformSampler`` draws
⌈C·K⌉ clients without replacement from its own PRNG stream (independent of
the training keys, so changing participation never reshuffles init/DP noise).

Samplers are *stateless*: the round's key is ``round_key(seed, round_idx)``,
a pure function of (seed, round index) with no carried RNG state. That is a
checkpoint/resume contract, not a style choice — a resumed run replays round
r's cohort exactly because nothing about earlier rounds feeds the draw.
Custom samplers must keep this property (derive per-round keys via
``round_key``/``fold_in``; never iterate a key across rounds).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax


def round_key(seed: int, round_idx: int):
    """Deterministic per-round PRNG key: ``fold_in(PRNGKey(seed), round)``.

    Shared by samplers and the failure models so every source of protocol
    randomness is replayable from (seed, round) alone — the property the
    resume-equivalence tests pin.
    """
    return jax.random.fold_in(jax.random.PRNGKey(seed), round_idx)


@dataclass(frozen=True)
class ClientSampler:
    """Full participation: every client, every round."""

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        return list(cids)


@dataclass(frozen=True)
class UniformSampler(ClientSampler):
    """Sample max(1, round(frac·K)) clients uniformly without replacement."""

    frac: float = 0.5
    seed: int = 0

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        k = len(cids)
        n = min(k, max(1, int(round(self.frac * k))))
        key = round_key(self.seed, round_idx)
        idx = jax.random.choice(key, k, shape=(n,), replace=False)
        return sorted(cids[int(i)] for i in idx)


@dataclass(frozen=True)
class FixedSizeSampler(ClientSampler):
    """Draw a cohort of exactly ``n`` clients per round (cross-device FL
    convention, and what the engine benchmark sweeps: cohort size is the
    knob, population size the backdrop)."""

    n: int = 1
    seed: int = 0

    def select(self, round_idx: int, cids: Sequence[int]) -> List[int]:
        k = len(cids)
        n = min(max(1, self.n), k)
        if n == k:
            return list(cids)
        key = round_key(self.seed, round_idx)
        idx = jax.random.choice(key, k, shape=(n,), replace=False)
        return sorted(cids[int(i)] for i in idx)
