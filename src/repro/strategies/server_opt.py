"""Server-side optimizers over the round pseudo-gradient (FedOpt family).

After ``Strategy.aggregate`` produces the merged adapters, the engine treats
Δ = merged − θ_global as a gradient estimate and lets a ``ServerOpt`` decide
the actual step (Reddi et al. 2021, "Adaptive Federated Optimization"):

    θ_global ← ServerOpt(θ_global, Δ)

``None`` (the default) is the identity: θ_global ← merged, which is exactly
the paper's Alg. 1 and the legacy behaviour.

Checkpoint contract: a ``ServerOpt`` is a stateless frozen dataclass; all
mutable state lives in the opt-state pytree threaded through ``apply``, and
``init(params)`` doubles as the *restore template* — ``RunState``
checkpoints save the moments and restore them into ``init``'s structure
with strict shape/dtype checks, which is why a killed FedAdam/FedAvgM run
resumes with its momentum intact instead of silently re-warming from zero.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.utils import tree_sub, tree_zeros_like


@dataclass(frozen=True)
class ServerOpt:
    """Identity server step (kept concrete so chains can be built uniformly)."""

    def init(self, params):
        return None

    def apply(self, opt_state, global_params, merged):
        """Returns (new global params, new opt state)."""
        return merged, opt_state


@dataclass(frozen=True)
class FedBuffOpt(ServerOpt):
    """Damped server step for buffered async aggregation (FedBuff, Nguyen
    et al. 2022): θ ← θ + lr·Δ. Identity at lr=1; lr<1 tempers merges built
    from stale buffered uploads."""

    lr: float = 1.0

    def apply(self, s, global_params, merged):
        new = jax.tree.map(lambda g, m: g + self.lr * (m - g), global_params, merged)
        return new, s


@dataclass(frozen=True)
class FedAvgMOpt(ServerOpt):
    """Server momentum: m ← β·m + Δ;  θ ← θ + lr·m (Hsu et al. 2019)."""

    lr: float = 1.0
    beta: float = 0.9

    def init(self, params):
        return tree_zeros_like(params)

    def apply(self, m, global_params, merged):
        delta = tree_sub(merged, global_params)
        m = jax.tree.map(lambda mm, d: self.beta * mm + d, m, delta)
        new = jax.tree.map(lambda g, mm: g + self.lr * mm, global_params, m)
        return new, m


@dataclass(frozen=True)
class FedAdamOpt(ServerOpt):
    """FedAdam: Adam moments over Δ, no bias correction (per the FedOpt
    paper); ``eps`` doubles as the adaptivity floor τ."""

    lr: float = 0.1
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3

    def init(self, params):
        return {"m": tree_zeros_like(params), "v": tree_zeros_like(params)}

    def apply(self, s, global_params, merged):
        delta = tree_sub(merged, global_params)
        m = jax.tree.map(lambda mm, d: self.b1 * mm + (1.0 - self.b1) * d,
                         s["m"], delta)
        v = jax.tree.map(lambda vv, d: self.b2 * vv + (1.0 - self.b2) * jnp.square(d),
                         s["v"], delta)
        new = jax.tree.map(
            lambda g, mm, vv: g + self.lr * mm / (jnp.sqrt(vv) + self.eps),
            global_params, m, v,
        )
        return new, {"m": m, "v": v}
