"""The three lowered step functions (one per input-shape kind).

These are the units the multi-pod dry-run compiles and the roofline
analyses. All three are pure jittable functions of (params, inputs):

  train_step    — FedNano federated training unit: NanoEdge forward (client
                  half) -> frozen backbone fwd+bwd (server half) -> AdamW on
                  adapter params ONLY + streaming Fisher accumulation. The
                  backbone receives no gradient (it is a constant w.r.t. the
                  differentiated argument) — exactly the paper's protocol.
  prefill_step  — forward over the prompt, returns decode state + last logits.
  decode_step   — ONE token against a seq_len cache/state.

For VLM/audio archs the batch includes stub patch embeddings; the text/image
NanoAdapters are applied client-side within the same program (the dry-run
lowers the fused client+server computation; the wire split is exercised by
repro.core.split and tested for gradient equivalence).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import adapters as adapters_lib
from repro.core.types import Batch
from repro.models import attention as attn_lib
from repro.models import model as model_lib
from repro.optim import adamw_update


def make_train_step(cfg, hp_lr: float = 1e-3):
    """(backbone, adapters, opt_state, batch) -> (adapters', opt_state', loss, fisher_sq)."""

    def train_step(backbone, adapters, opt_state, batch: Batch):
        def loss_fn(adp):
            loss, aux = adapters_lib.fednano_loss(cfg, backbone, adp, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(adapters)
        new_adapters, new_opt = adamw_update(grads, opt_state, adapters, lr=hp_lr)
        fisher_sq = jax.tree.map(lambda g: jnp.square(g.astype(jnp.float32)), grads)
        return new_adapters, new_opt, loss, fisher_sq

    return train_step


def make_prefill_step(cfg, capacity: int):
    """(backbone, adapters, batch) -> (state, last_logits)."""

    def prefill_step(backbone, adapters, batch: Batch):
        embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
            cfg, backbone, adapters, batch
        )
        state, hidden = model_lib.prefill(cfg, backbone, embeds, positions, capacity,
                                          enc_embeds=enc)
        last = model_lib.logits(cfg, backbone, hidden[:, -1:, :])
        return state, last

    return prefill_step


def make_decode_step(cfg):
    """(backbone, adapters, state, token, pos) -> (logits, state').

    token (B,) int32; the client-side NanoAdapter-T is applied to the new
    token's embedding before it enters the backbone (split serving).
    """

    def decode_step(backbone, adapters, state, token, pos):
        emb = model_lib.embed_tokens(cfg, backbone, token[:, None])  # (B, 1, D)
        if "text" in adapters:
            emb = adapters_lib.nano_adapter_apply(
                adapters["text"], emb,
                rank=cfg.adapter.rank, alpha=cfg.adapter.alpha,
                use_pallas=cfg.use_pallas,
            )
        lg, state = model_lib.decode_step(cfg, backbone, emb, state, pos)
        return lg, state

    return decode_step


# ---------------------------------------------------------------------------
# abstract input builders (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def text_seq_len(cfg, seq_len: int) -> int:
    """Text-token count so that image patches + text == seq_len total."""
    if cfg.family == "audio":
        return seq_len  # decoder positions; encoder stream is separate
    if cfg.frontend_dim:
        from repro.models.vision_stub import num_patches

        return max(seq_len - num_patches(cfg), 8)
    return seq_len


def batch_specs(cfg, batch: int, seq_len: int) -> Batch:
    """Abstract Batch for train/prefill shapes."""
    s_text = text_seq_len(cfg, seq_len)
    patches = None
    if cfg.frontend_dim:
        from repro.models.vision_stub import num_patches

        m = cfg.enc_seq_len if cfg.family == "audio" else num_patches(cfg)
        patches = _sds((batch, m, cfg.frontend_dim), jnp.dtype(cfg.dtype))
    return Batch(
        tokens=_sds((batch, s_text), jnp.int32),
        labels=_sds((batch, s_text), jnp.int32),
        mask=_sds((batch, s_text), jnp.float32),
        patches=patches,
    )


def input_specs(cfg, shape_cfg):
    """ShapeDtypeStruct stand-ins for every model input of a workload.

    Returns a dict with keys depending on shape_cfg.kind:
      train:   {batch}
      prefill: {batch}
      decode:  {state, token, pos}
    """
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    if shape_cfg.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, b, s)}
    # decode: state with capacity seq_len + 1 new token
    dtype = jnp.dtype(cfg.dtype)
    state = jax.eval_shape(
        lambda: model_lib.init_state(cfg, b, s, dtype)
    )
    return {
        "state": state,
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def adapter_specs(cfg):
    return jax.eval_shape(
        lambda: adapters_lib.init_nanoedge(jax.random.PRNGKey(0), cfg)
    )


def backbone_specs(cfg):
    return jax.eval_shape(
        lambda: model_lib.init_backbone(jax.random.PRNGKey(0), cfg)
    )


def opt_state_specs(cfg):
    from repro.optim import adamw_init

    return jax.eval_shape(adamw_init, adapter_specs(cfg))


# ---------------------------------------------------------------------------
# workload policy (shared by dryrun + tests; no jax device side effects here)
# ---------------------------------------------------------------------------

def shape_supported(cfg, shape_cfg) -> tuple[bool, str]:
    """long_500k needs sub-quadratic sequence mixing (DESIGN.md §4)."""
    if shape_cfg.name == "long_500k":
        if cfg.family == "audio":
            return False, "enc-dec audio backbone: fixed 1500-frame encoder context"
        if not cfg.subquadratic:
            return False, "pure full-attention arch (no SWA/block-sparse variant)"
    return True, ""


def exec_config(cfg, shape_cfg, mode: str, overrides: dict | None = None):
    """Execution-config view for a dry-run.

    mode "full":     scanned layers (production path, proves compile+fits),
                     blockwise-softmax attention for long prefill.
    mode "roofline": UNROLLED layers at reduced depths — XLA cost_analysis
                     counts while-loop bodies once, so the roofline lowering
                     must unroll; run_roofline extrapolates to full depth.
    """
    kw = {}
    if shape_cfg.kind == "prefill":
        # §Perf qwen1.5: context-parallel queries win for prefill but the
        # backward of the layout regresses training -> prefill-only default.
        kw["ctx_parallel_attn"] = True
    if mode == "full":
        if shape_cfg.kind != "decode":
            kw["attn_chunk"] = 1024
    else:
        kw["scan_layers"] = False
        kw["attn_chunk"] = None
    if overrides:
        kw.update(overrides)
    return cfg.with_(**kw)


def _depth_points(cfg):
    """Unroll depths for the linear extrapolation (see run_roofline)."""
    if cfg.family == "audio":
        return "exact", [cfg.n_layers]          # 6+6 whisper: unroll fully
    if cfg.family == "ssm":
        return "exact", [cfg.n_layers]          # 24 small layers: unroll fully
    if cfg.family == "hybrid":
        return "hybrid", [3, 6, 8]              # (1 triple), (2 triples), (2 triples + 2 rec)
    return "linear", [2, 4]
