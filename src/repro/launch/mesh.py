"""Production mesh definitions.

Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — batch/client
parallelism spans (pod, data); tensor parallelism never crosses pods (only
parameter-plane collectives ride the DCN).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real 1-CPU topology).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests on CPU: 1×1)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per-direction, per chip)
