"""Federated training driver (``python -m repro.launch.train``).

Runs the full FedNano protocol (or any baseline strategy) on a reduced
backbone with the synthetic non-IID VQA corpus — the runnable end-to-end
entry point (examples/federated_vqa.py wraps this with a narrative).

On a real TPU fleet the same step functions lower onto the production mesh
(see repro.launch.dryrun); here they run on host CPU with the smoke-scale
configs. Checkpoints + metrics land under --out.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import save_server_checkpoint
from repro.configs import get_smoke_config, list_archs
from repro.core import FailureModel, HyperParams, run_centralized, run_federated
from repro.data import make_federated_data
from repro.strategies import UniformSampler, available_strategies
from repro.strategies.server_opt import FedAdamOpt, FedAvgMOpt


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llava-1.5-7b", choices=list_archs())
    ap.add_argument("--strategy", default="fednano",
                    choices=list(available_strategies()) + ["centralized"])
    ap.add_argument("--server-opt", default=None, choices=["fedavgm", "fedadam"],
                    help="FedOpt server step applied to the merged pseudo-gradient")
    ap.add_argument("--server-lr", type=float, default=None,
                    help="server-optimizer learning rate (default: the opt's own)")
    ap.add_argument("--client-frac", type=float, default=1.0,
                    help="fraction of clients sampled per round (C in C·K)")
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "vmap", "sharded", "buffered"],
                    help="round engine: per-client loop, vectorized vmap/scan "
                         "cohort, the vmap layout sharded over a clients "
                         "device mesh, or FedBuff-style buffered async")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size for --engine sharded (default: all "
                         "visible devices; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the sharded engine's prepare/compute "
                         "double buffer")
    ap.add_argument("--agg-chunk", type=int, default=None,
                    help="fold cohort chunks of this size into a streaming "
                         "merge (O(chunk) server memory; vmap engine)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="server buffer size for --engine buffered "
                         "(default: half the population)")
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--rank", type=int, default=None, help="NanoAdapter rank override")
    ap.add_argument("--examples-per-client", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/train")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the full round state every N rounds under "
                         "<out>/state (0 = only the final snapshot)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from a RunState snapshot directory (pass the "
                         "snapshot itself or its parent; LATEST is followed). "
                         "Use the same seed/arch/strategy flags as the "
                         "original run — replay is deterministic")
    ap.add_argument("--dropout-prob", type=float, default=0.0,
                    help="per-round probability a sampled client never starts")
    ap.add_argument("--crash-prob", type=float, default=0.0,
                    help="per-round probability a client dies mid-update "
                         "(download charged, progress lost)")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="probability a buffered-engine client is delayed")
    ap.add_argument("--failure-seed", type=int, default=0,
                    help="seed for the failure schedule (independent of --seed)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route LoRA/Fisher-merge through the Pallas kernels (interpret mode)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if args.rank:
        import dataclasses

        cfg = cfg.with_(adapter=dataclasses.replace(cfg.adapter, rank=args.rank))
    if args.use_pallas:
        cfg = cfg.with_(use_pallas=True)

    print(f"== FedNano driver: arch={args.arch} (smoke config) strategy={args.strategy} "
          f"K={args.clients} R={args.rounds} α={args.alpha} rank={cfg.adapter.rank}")
    train, evald, _ = make_federated_data(
        cfg, n_clients=args.clients, examples_per_client=args.examples_per_client,
        alpha=args.alpha, batch_size=args.batch_size, seq_len=args.seq_len,
        seed=args.seed,
    )
    hp = HyperParams(lr=args.lr, local_steps=args.local_steps)
    key = jax.random.PRNGKey(args.seed)
    t0 = time.time()
    if args.strategy == "centralized":
        res = run_centralized(key, cfg, train, evald,
                              steps=args.rounds * args.local_steps * args.clients,
                              hp=hp, verbose=True)
    else:
        server_opt = None
        if args.server_opt:
            cls = {"fedavgm": FedAvgMOpt, "fedadam": FedAdamOpt}[args.server_opt]
            server_opt = cls(lr=args.server_lr) if args.server_lr is not None else cls()
        sampler = UniformSampler(frac=args.client_frac, seed=args.seed) \
            if args.client_frac < 1.0 else None
        failures = None
        if args.dropout_prob or args.crash_prob or args.straggler_prob:
            failures = FailureModel(dropout_prob=args.dropout_prob,
                                    crash_prob=args.crash_prob,
                                    straggler_prob=args.straggler_prob,
                                    seed=args.failure_seed)
        res = run_federated(key, cfg, train, evald, strategy=args.strategy,
                            rounds=args.rounds, hp=hp, verbose=True,
                            use_pallas=args.use_pallas,
                            server_opt=server_opt, sampler=sampler,
                            engine=args.engine, agg_chunk=args.agg_chunk,
                            devices=args.devices,
                            overlap=not args.no_overlap,
                            buffer_size=args.buffer_size,
                            failures=failures,
                            checkpoint_dir=os.path.join(args.out, "state"),
                            checkpoint_every=args.checkpoint_every,
                            resume=args.resume)
    dt = time.time() - t0

    os.makedirs(args.out, exist_ok=True)
    summary = {
        "arch": args.arch,
        "strategy": args.strategy,
        "avg_accuracy": res.avg_accuracy,
        "client_accuracy": res.client_accuracy,
        "rounds": res.round_metrics,
        "comm_totals": res.comm_totals,
        "wall_s": dt,
    }
    with open(os.path.join(args.out, f"{args.arch}_{args.strategy}.json"), "w") as f:
        json.dump(summary, f, indent=1)
    if res.server is not None:
        save_server_checkpoint(os.path.join(args.out, "ckpt"), res.server,
                               round_idx=args.rounds,
                               server_opt_state=res.server_opt_state,
                               rng_key=key)
    print(f"== done in {dt:.1f}s: avg client accuracy {res.avg_accuracy:.4f}")
    print(f"   per-client: { {k: round(v, 4) for k, v in res.client_accuracy.items()} }")
    if res.comm_totals:
        up = res.comm_totals["param_up"] / 1024**2
        print(f"   param-plane traffic: {up:.2f} MiB up over {args.rounds} rounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
