"""Federated training driver (``python -m repro.launch.train``).

Runs the full FedNano protocol (or any baseline strategy) on a reduced
backbone with the synthetic non-IID VQA corpus — the runnable end-to-end
entry point (examples/federated_vqa.py wraps this with a narrative).

On a real TPU fleet the same step functions lower onto the production mesh
(see repro.launch.dryrun); here they run on host CPU with the smoke-scale
configs. Checkpoints + metrics land under --out.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import save_server_checkpoint
from repro.configs import get_smoke_config, list_archs
from repro.core import HyperParams, run_centralized, run_federated
from repro.data import make_federated_data
from repro.strategies import UniformSampler, available_strategies
from repro.strategies.server_opt import FedAdamOpt, FedAvgMOpt


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llava-1.5-7b", choices=list_archs())
    ap.add_argument("--strategy", default="fednano",
                    choices=list(available_strategies()) + ["centralized"])
    ap.add_argument("--server-opt", default=None, choices=["fedavgm", "fedadam"],
                    help="FedOpt server step applied to the merged pseudo-gradient")
    ap.add_argument("--server-lr", type=float, default=None,
                    help="server-optimizer learning rate (default: the opt's own)")
    ap.add_argument("--client-frac", type=float, default=1.0,
                    help="fraction of clients sampled per round (C in C·K)")
    ap.add_argument("--engine", default="sequential",
                    choices=["sequential", "vmap", "buffered"],
                    help="round engine: per-client loop, vectorized vmap/scan "
                         "cohort, or FedBuff-style buffered async")
    ap.add_argument("--agg-chunk", type=int, default=None,
                    help="fold cohort chunks of this size into a streaming "
                         "merge (O(chunk) server memory; vmap engine)")
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="server buffer size for --engine buffered "
                         "(default: half the population)")
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--rank", type=int, default=None, help="NanoAdapter rank override")
    ap.add_argument("--examples-per-client", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/train")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route LoRA/Fisher-merge through the Pallas kernels (interpret mode)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if args.rank:
        import dataclasses

        cfg = cfg.with_(adapter=dataclasses.replace(cfg.adapter, rank=args.rank))
    if args.use_pallas:
        cfg = cfg.with_(use_pallas=True)

    print(f"== FedNano driver: arch={args.arch} (smoke config) strategy={args.strategy} "
          f"K={args.clients} R={args.rounds} α={args.alpha} rank={cfg.adapter.rank}")
    train, evald, _ = make_federated_data(
        cfg, n_clients=args.clients, examples_per_client=args.examples_per_client,
        alpha=args.alpha, batch_size=args.batch_size, seq_len=args.seq_len,
        seed=args.seed,
    )
    hp = HyperParams(lr=args.lr, local_steps=args.local_steps)
    key = jax.random.PRNGKey(args.seed)
    t0 = time.time()
    if args.strategy == "centralized":
        res = run_centralized(key, cfg, train, evald,
                              steps=args.rounds * args.local_steps * args.clients,
                              hp=hp, verbose=True)
    else:
        server_opt = None
        if args.server_opt:
            cls = {"fedavgm": FedAvgMOpt, "fedadam": FedAdamOpt}[args.server_opt]
            server_opt = cls(lr=args.server_lr) if args.server_lr is not None else cls()
        sampler = UniformSampler(frac=args.client_frac, seed=args.seed) \
            if args.client_frac < 1.0 else None
        res = run_federated(key, cfg, train, evald, strategy=args.strategy,
                            rounds=args.rounds, hp=hp, verbose=True,
                            use_pallas=args.use_pallas,
                            server_opt=server_opt, sampler=sampler,
                            engine=args.engine, agg_chunk=args.agg_chunk,
                            buffer_size=args.buffer_size)
    dt = time.time() - t0

    os.makedirs(args.out, exist_ok=True)
    summary = {
        "arch": args.arch,
        "strategy": args.strategy,
        "avg_accuracy": res.avg_accuracy,
        "client_accuracy": res.client_accuracy,
        "rounds": res.round_metrics,
        "comm_totals": res.comm_totals,
        "wall_s": dt,
    }
    with open(os.path.join(args.out, f"{args.arch}_{args.strategy}.json"), "w") as f:
        json.dump(summary, f, indent=1)
    if res.server is not None:
        save_server_checkpoint(os.path.join(args.out, "ckpt"), res.server,
                               round_idx=args.rounds)
    print(f"== done in {dt:.1f}s: avg client accuracy {res.avg_accuracy:.4f}")
    print(f"   per-client: { {k: round(v, 4) for k, v in res.client_accuracy.items()} }")
    if res.comm_totals:
        up = res.comm_totals["param_up"] / 1024**2
        print(f"   param-plane traffic: {up:.2f} MiB up over {args.rounds} rounds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
