"""Parameter / input / state sharding rules (DESIGN.md §5).

Maps every pytree leaf to a logical PartitionSpec by (path, shape). Logical
axes: "data" (aliased to ("pod","data") on the multi-pod mesh by
repro.sharding) and "model". Non-divisible dims automatically fall back to
replication via ``resolve_spec`` — this implements the documented fallbacks
(qwen1.5 20 heads, glm4 kv=2, mamba2/whisper vocab, KV-cache head_dim
sharding when kv-heads don't divide the model axis).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import resolve_spec

# layer-stack containers: leaves under these have a leading layer dim
_STACKED = ("layers", "triples", "extras", "enc_layers", "dec_layers")

BATCH = "data"  # alias expanded to ("pod", "data") by the resolver


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return tuple(names)


def param_logical_spec(path_names: Tuple[str, ...], shape: Tuple[int, ...],
                       kind: str = "train"):
    """Logical spec for a PARAMETER leaf (pre layer-stack adjustment).

    ``kind`` selects the workload-aware MoE expert layout when the expert
    count doesn't divide the model axis (grok: 8 experts vs 16):
      * train/prefill — token-sharded activations: experts 2D-sharded over
        (data, model) with the token groups staying on ``data``.
      * decode — weight-stationary: the FFN width F sharded over the FULL
        (data × model) mesh so the single-token expert matmuls reduce with
        one small fp32 all-reduce instead of all-gathering 400 MB of expert
        weights per layer per token (EXPERIMENTS.md §Perf, grok/decode it. 2).
    """
    name = path_names[-1] if path_names else ""
    nd = len(shape)

    # --- embeddings ---
    if name == "table":
        return ("model", None)  # vocab-sharded; falls back when V % 16 != 0
    if name == "pos":
        return (None, None)

    # --- MoE expert weights (E, D, F) / (E, F, D) ---
    if "moe" in path_names and name in ("w_gate", "w_up") and nd == 3:
        if shape[0] % 16 == 0:
            return ("model", None, None)
        if kind == "decode":
            return (None, None, ("data", "model"))
        return (None, "data", "model")
    if "moe" in path_names and name == "w_down" and nd == 3:
        if shape[0] % 16 == 0:
            return ("model", None, None)
        if kind == "decode":
            return (None, ("data", "model"), None)
        return (None, "model", "data")
    if name == "router":
        return (None,) * nd

    # --- dense MLP ---
    if name in ("w_gate", "w_up"):
        return (None, "model")
    if name == "w_down":
        return ("model", None)

    # --- attention ---
    if name in ("wq", "wk", "wv"):
        return (None, "model")
    if name == "wo":
        return ("model", None)
    if name in ("bq", "bk", "bv"):
        return ("model",)

    # --- mamba2 ---
    if name == "in_proj":
        return (None, "model")
    if name == "out_proj":
        return ("model", None)
    if name == "conv_w":
        return (None, "model")

    # --- RG-LRU ---
    if name in ("w_gate_branch", "w_rec_branch"):
        return (None, "model")
    if name in ("w_a", "w_x"):
        return (None, "model")
    if name == "w_out":
        return ("model", None)

    # norms, biases, gates, adapters, connector: replicate
    return (None,) * nd


def spec_for_param(path, leaf, kind: str = "train") -> Tuple:
    names = _path_names(path)
    shape = tuple(leaf.shape)
    stacked = any(n in _STACKED for n in names)
    if stacked and shape:
        inner = param_logical_spec(names, shape[1:], kind)
        return (None,) + tuple(inner)
    return tuple(param_logical_spec(names, shape, kind))


def make_param_shardings(mesh: Mesh, params, kind: str = "train"):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def f(path, leaf):
        spec = spec_for_param(path, leaf, kind)
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, spec))

    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# inputs / decode state
# ---------------------------------------------------------------------------

def batch_spec(ndim: int):
    """tokens/labels/mask (B, S[, ...]): batch over (pod, data)."""
    return (BATCH,) + (None,) * (ndim - 1)


def make_batch_shardings(mesh: Mesh, batch):
    def f(leaf):
        return NamedSharding(mesh, resolve_spec(mesh, leaf.shape, batch_spec(leaf.ndim)))

    return jax.tree.map(f, batch)


def _kv_cache_spec(mesh: Mesh, shape):
    """(L, B, C, kv, hd): batch over (pod,data); kv over model when divisible,
    else head_dim over model (the documented fallback), else replicated."""
    model = mesh.shape.get("model", 1)
    l, b, c, kv, hd = shape
    if kv % model == 0:
        return (None, BATCH, None, "model", None)
    if hd % model == 0:
        return (None, BATCH, None, None, "model")
    return (None, BATCH, None, None, None)


def make_state_shardings(mesh: Mesh, state):
    """Decode-state pytree: KV caches (5D), SSM/RG-LRU states (3-5D)."""

    def f(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 5:  # stacked KVCache (L, B, C, kv, hd)
            spec = _kv_cache_spec(mesh, shape)
        elif len(shape) == 4:  # stacked SSM h (L, B, H, P*) or rglru conv (L, B, w, dr)
            spec = (None, BATCH, None, None)
        elif len(shape) == 3:  # stacked rglru h (L, B, dr)
            spec = (None, BATCH, "model")
        elif len(shape) == 2:
            spec = (BATCH, None)
        else:
            spec = (None,) * len(shape)
        # stacked SSM state h is (L, B, H, P, N) = 5D too — disambiguate by a
        # heuristic: KV caches have dim2 (capacity) >= 64 and dim3 (kv heads)
        # small; SSM h has dim2 = heads. Use path names instead when present.
        names = _path_names(path)
        if "h" in names and len(shape) == 5:
            spec = (None, BATCH, None, None, None)
        if "conv" in names:
            spec = (None, BATCH, None, None)[: len(shape)]
        return NamedSharding(mesh, resolve_spec(mesh, shape, spec))

    return jax.tree_util.tree_map_with_path(f, state)


def replicated(mesh: Mesh, tree):
    def f(leaf):
        return NamedSharding(mesh, P())

    return jax.tree.map(f, tree)
