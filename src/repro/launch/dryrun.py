import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The FIRST two lines above run before ANY other import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512 host
placeholder devices so ``jax.make_mesh`` can build the production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh pod --out benchmarks/results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --list

Per pair this prints compiled.memory_analysis() (proves it fits) and
cost_analysis() (FLOPs/bytes for §Roofline), parses collective bytes from
the optimized HLO, and optionally writes a JSON record consumed by
benchmarks/roofline_table.py.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import roofline as roofline_lib
from repro.launch import sharding_rules as rules
from repro.launch import steps as steps_lib
from repro.launch.steps import exec_config, shape_supported, _depth_points
from repro.launch.mesh import make_production_mesh
from repro.sharding import use_mesh


def build_lowerable(cfg, shape_cfg, mesh):
    """Returns (jitted fn, arg specs) for the workload."""
    ins = steps_lib.input_specs(cfg, shape_cfg)
    backbone = steps_lib.backbone_specs(cfg)
    adapters = steps_lib.adapter_specs(cfg)
    b_shard = rules.make_param_shardings(mesh, backbone, kind=shape_cfg.kind)
    a_shard = rules.replicated(mesh, adapters)

    if shape_cfg.kind == "train":
        opt = steps_lib.opt_state_specs(cfg)
        o_shard = rules.replicated(mesh, opt)
        batch = ins["batch"]
        batch_shard = rules.make_batch_shardings(mesh, batch)
        fn = steps_lib.make_train_step(cfg)
        jitted = jax.jit(
            fn, in_shardings=(b_shard, a_shard, o_shard, batch_shard)
        )
        args = (backbone, adapters, opt, batch)
        return jitted, args

    if shape_cfg.kind == "prefill":
        batch = ins["batch"]
        batch_shard = rules.make_batch_shardings(mesh, batch)
        fn = steps_lib.make_prefill_step(cfg, capacity=shape_cfg.seq_len)
        jitted = jax.jit(fn, in_shardings=(b_shard, a_shard, batch_shard))
        args = (backbone, adapters, batch)
        return jitted, args

    # decode — the state buffer is donated (in/out aliased KV cache, the
    # standard serving discipline; without it the cache is double-counted)
    state = ins["state"]
    s_shard = rules.make_state_shardings(mesh, state)
    fn = steps_lib.make_decode_step(cfg)
    from jax.sharding import NamedSharding, PartitionSpec as P

    tok_shard = rules.make_batch_shardings(mesh, ins["token"])
    pos_shard = NamedSharding(mesh, P())
    jitted = jax.jit(
        fn,
        in_shardings=(b_shard, a_shard, s_shard, tok_shard, pos_shard),
        donate_argnums=(2,),
    )
    args = (backbone, adapters, state, ins["token"], ins["pos"])
    return jitted, args


def _sharded_bytes(tree, shardings) -> int:
    """Exact per-device bytes of a pytree under its NamedShardings."""
    import numpy as np

    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        shard_shape = sh.shard_shape(leaf.shape)
        total += int(np.prod(shard_shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def analytic_footprint(cfg, shape_cfg, mesh) -> dict:
    """Per-device HBM footprint: sharded params + adapters + opt + inputs/state.

    This is the TPU 'does it fit' number; the XLA-CPU memory_analysis temp
    numbers double-count while-loop buffers (no in-place loop aliasing on the
    CPU backend) and are reported alongside as an upper bound.
    """
    backbone = steps_lib.backbone_specs(cfg)
    adapters = steps_lib.adapter_specs(cfg)
    b_bytes = _sharded_bytes(backbone, rules.make_param_shardings(mesh, backbone, kind=shape_cfg.kind))
    a_bytes = _sharded_bytes(adapters, rules.replicated(mesh, adapters))
    out = {"params": b_bytes, "adapters": a_bytes}
    ins = steps_lib.input_specs(cfg, shape_cfg)
    if shape_cfg.kind == "train":
        opt = steps_lib.opt_state_specs(cfg)
        out["opt"] = _sharded_bytes(opt, rules.replicated(mesh, opt))
        out["inputs"] = _sharded_bytes(ins["batch"], rules.make_batch_shardings(mesh, ins["batch"]))
    elif shape_cfg.kind == "prefill":
        out["inputs"] = _sharded_bytes(ins["batch"], rules.make_batch_shardings(mesh, ins["batch"]))
        from repro.models import model as model_lib

        state = jax.eval_shape(lambda: model_lib.init_state(
            cfg, shape_cfg.global_batch, shape_cfg.seq_len, jnp.dtype(cfg.dtype)))
        out["state_out"] = _sharded_bytes(state, rules.make_state_shardings(mesh, state))
    else:
        out["state"] = _sharded_bytes(ins["state"], rules.make_state_shardings(mesh, ins["state"]))
    # activation workspace allowance: 4 live (B_loc, S, D) fp32 buffers
    n_batch_shards = 1
    for ax in ("pod", "data"):
        n_batch_shards *= mesh.shape.get(ax, 1)
    b_loc = max(shape_cfg.global_batch // n_batch_shards, 1)
    s = shape_cfg.seq_len if shape_cfg.kind != "decode" else 1
    out["workspace_est"] = 4 * b_loc * s * cfg.d_model * 4
    out["total"] = sum(out.values())
    return out


def _compile_once(cfg, shape_cfg, mesh):
    """lower + compile; returns (cost dict, hlo text, memory stats, timings)."""
    t0 = time.time()
    with use_mesh(mesh):
        jitted, args = build_lowerable(cfg, shape_cfg, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
    return dict(cost) if cost else {}, hlo, mem, (t_lower, t_compile)


def _measure(cfg, shape_cfg, mesh):
    cost, hlo, mem, _ = _compile_once(cfg, shape_cfg, mesh)
    coll = roofline_lib.collective_bytes_from_hlo(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(v for k, v in coll.items() if k != "count")),
        "coll_breakdown": coll,
    }


def _lin(points, depths, full_depth):
    """Linear extrapolation of each metric to full depth."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        if len(points) == 1:
            out[key] = points[0][key]
        else:
            d = (points[1][key] - points[0][key]) / (depths[1] - depths[0])
            out[key] = points[0][key] + d * (full_depth - depths[0])
    return out


def run_roofline(arch: str, shape_name: str, overrides: dict | None = None,
                 out_dir: str | None = None, verbose: bool = True, tag: str = "") -> dict:
    """Roofline terms on the single-pod mesh via unrolled-depth extrapolation.

    XLA cost_analysis counts while-loop (scan) bodies once, so we lower the
    SAME step UNROLLED at reduced depths and extrapolate linearly in depth
    (exact for homogeneous stacks; hybrid gets a per-recurrent-layer
    correction; small archs are unrolled fully). Validation of this
    methodology vs a fully-unrolled 40-layer compile is in EXPERIMENTS.md.
    """
    cfg0 = get_config(arch)
    shape_cfg = INPUT_SHAPES[shape_name]
    ok, why = shape_supported(cfg0, shape_cfg)
    rec = {"arch": arch, "shape": shape_name, "mesh": "pod", "mode": "roofline",
           "tag": tag, "status": "skip", "reason": why, "overrides": overrides or {}}
    if not ok:
        if verbose:
            print(f"[skip] roofline {arch} × {shape_name}: {why}")
        _maybe_write(out_dir, rec, tag)
        return rec

    mesh = make_production_mesh(multi_pod=False)
    chips = int(len(mesh.devices.flat))
    t0 = time.time()
    try:
        kind, depths = _depth_points(cfg0)
        points = []
        for L in depths:
            cfg = exec_config(cfg0.with_(n_layers=L), shape_cfg, "roofline", overrides)
            points.append(_measure(cfg, shape_cfg, mesh))
        if kind == "exact":
            est = {k: points[0][k] for k in ("flops", "bytes", "coll")}
        elif kind == "hybrid":
            # f(3)=f0+t, f(6)=f0+2t, f(8)=f(6)+2r  ->  full = f0 + 12t + 2r
            est = {}
            for k in ("flops", "bytes", "coll"):
                t = points[1][k] - points[0][k]
                r = (points[2][k] - points[1][k]) / 2.0
                f0 = points[0][k] - t
                n_t, n_e = cfg0.n_layers // 3, cfg0.n_layers % 3
                est[k] = f0 + n_t * t + n_e * r
        else:
            est = _lin(points, depths, cfg0.n_layers)

        rep = roofline_lib.analyze(
            arch=arch, shape=shape_name, mesh_name="pod", chips=chips,
            cost={"flops": est["flops"], "bytes accessed": est["bytes"]},
            hlo_text="", model_flops=roofline_lib.model_flops_estimate(cfg0, shape_cfg),
        )
        # patch the collective term with the extrapolated value
        rep.collective_bytes = est["coll"]
        rep.t_collective = est["coll"] / roofline_lib.ICI_BW
        terms = {"compute": rep.t_compute, "memory": rep.t_memory,
                 "collective": rep.t_collective}
        rep.bottleneck = max(terms, key=terms.get)
        rep.collective_breakdown = points[-1]["coll_breakdown"]
        rec.update(rep.to_dict())
        rec["status"] = "ok"
        rec["depth_points"] = {"kind": kind, "depths": depths, "points": points}
        rec["wall_s"] = round(time.time() - t0, 1)
        if verbose:
            print(f"[roofline] {arch} × {shape_name} ({kind} @ {depths}, {rec['wall_s']}s{' ' + tag if tag else ''})")
            print(f"     flops={rep.hlo_flops:.3e} bytes={rep.hlo_bytes:.3e} coll={rep.collective_bytes:.3e}")
            print(f"     compute {rep.t_compute*1e3:.2f}ms | memory {rep.t_memory*1e3:.2f}ms | "
                  f"collective {rep.t_collective*1e3:.2f}ms -> {rep.bottleneck}-bound; "
                  f"useful {100*rep.useful_ratio:.0f}%")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERROR] roofline {arch} × {shape_name}: {rec['error']}")
    _maybe_write(out_dir, rec, tag)
    return rec


def run_pair(arch: str, shape_name: str, mesh_kind: str, out_dir: str | None = None,
             verbose: bool = True, overrides: dict | None = None) -> dict:
    """Full-config scanned dry-run: proves lower+compile+fits for the pair."""
    cfg = get_config(arch)
    shape_cfg = INPUT_SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape_cfg)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": "full",
        "status": "skip", "reason": why,
    }
    if not ok:
        if verbose:
            print(f"[skip] {arch} × {shape_name}: {why}")
        _maybe_write(out_dir, rec)
        return rec

    cfg = exec_config(cfg, shape_cfg, "full", overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = int(len(mesh.devices.flat))
    try:
        cost, hlo, mem, (t_lower, t_compile) = _compile_once(cfg, shape_cfg, mesh)
        mem_str = str(mem)
        bytes_per_dev = None
        try:
            bytes_per_dev = (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ) or None
        except Exception:
            pass

        rep = roofline_lib.analyze(
            arch=arch, shape=shape_name, mesh_name=mesh_kind, chips=chips,
            cost=cost, hlo_text=hlo,
            model_flops=roofline_lib.model_flops_estimate(cfg, shape_cfg),
            bytes_per_device=bytes_per_dev,
            notes="scanned module: per-layer costs counted once by XLA; see roofline mode",
        )
        foot = analytic_footprint(cfg, shape_cfg, mesh)
        rec.update(rep.to_dict())
        rec["status"] = "ok"
        rec["t_lower_s"] = round(t_lower, 1)
        rec["t_compile_s"] = round(t_compile, 1)
        rec["memory_analysis"] = mem_str
        rec["analytic_footprint"] = foot
        if verbose:
            print(f"[ok] {arch} × {shape_name} × {mesh_kind} "
                  f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
            print(f"     memory_analysis: {mem_str}")
            fit = "FITS" if foot["total"] <= 16 * 1024**3 else "OVER v5e 16GiB"
            print(f"     analytic bytes/device: {foot['total']/1024**3:.2f} GiB -> {fit} "
                  f"({ {k: round(v/1024**3, 3) for k, v in foot.items() if k != 'total'} } GiB)")
            if bytes_per_dev:
                print(f"     xla-cpu bytes/device (upper bound, no loop aliasing): "
                      f"{bytes_per_dev/1024**3:.2f} GiB")
            print(f"     collectives (scanned module): {rep.collective_bytes:.3e} B "
                  f"{ {k: v for k, v in rep.collective_breakdown.items() if v} }")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[ERROR] {arch} × {shape_name} × {mesh_kind}: {rec['error']}")
    _maybe_write(out_dir, rec)
    return rec


def _maybe_write(out_dir, rec, tag: str = ""):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    mode = rec.get("mode", "full")
    suffix = f"__{tag}" if tag else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{mode}{suffix}.json".replace("/", "_")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--mode", choices=["full", "roofline", "both"], default="full")
    ap.add_argument("--all", action="store_true", help="all archs × all shapes")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-pair JSON records")
    ap.add_argument("--tag", default="", help="suffix for hillclimb variants")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. loss_chunk=1024)")
    args = ap.parse_args(argv)

    if args.list:
        for a in ASSIGNED_ARCHS:
            print(a)
        return 0

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    archs = ASSIGNED_ARCHS if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_err = 0
    for arch in archs:
        for shape in shapes:
            if args.mode in ("full", "both"):
                for mesh_kind in meshes:
                    rec = run_pair(arch, shape, mesh_kind, out_dir=args.out,
                                   overrides=overrides or None)
                    if rec["status"] == "error":
                        n_err += 1
            if args.mode in ("roofline", "both"):
                rec = run_roofline(arch, shape, overrides=overrides or None,
                                   out_dir=args.out, tag=args.tag)
                if rec["status"] == "error":
                    n_err += 1
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
