"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all in seconds (v5e constants from
repro.launch.mesh). ``compiled.cost_analysis()`` describes the **per-device
SPMD module** (the lowered HLO is one device's program), so the terms are
directly per-chip — equivalent to the spec's global/(chips×peak) form:

    compute    = HLO_FLOPs_per_device / 197 TF/s        (= global/(chips×peak))
    memory     = HLO_bytes_per_device / 819 GB/s
    collective = collective_bytes_per_device / 50 GB/s

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum the
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (methodology note: output bytes ≈ bytes
crossing links for AG/AR up to the (n-1)/n ring factor; we report the raw
sum and treat it as an upper-ish bound consistently across iterations, which
is what the hillclimb needs).

Also computed: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _first_shape_bytes(line: str) -> int:
    """Bytes of the result shape(s) at the start of an HLO instruction line."""
    # instruction form: "%name = TYPE[dims]{layout} op-name(...)" or tuple
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    op_pos = min((rhs.find(c) for c in _COLLECTIVES if rhs.find(c) >= 0), default=-1)
    if op_pos < 0:
        return 0
    result_part = rhs[:op_pos]
    total = 0
    for m in _SHAPE_RE.finditer(result_part):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the optimized HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "fusion" in s.split("(")[0]:
            continue
        for c in _COLLECTIVES:
            # match the op name as the instruction (e.g. " = bf16[..] all-gather(")
            if re.search(rf"=\s*[^=]*\b{c}(-start|-done)?\(", s):
                if c + "-done" in s:
                    continue  # avoid double counting start/done pairs
                b = _first_shape_bytes(s)
                out[c] += b
                out["count"] += 1
                break
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    bytes_per_device: Optional[float] = None
    notes: str = ""

    def to_dict(self):
        return asdict(self)


def analyze(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: Dict, hlo_text: str, model_flops: float,
    bytes_per_device: Optional[float] = None, notes: str = "",
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    # cost_analysis reports bytes accessed across operands+outputs
    nbytes = float(cost.get("bytes accessed", cost.get("bytes_accessed", 0.0)))
    coll = collective_bytes_from_hlo(hlo_text)
    coll_bytes = float(sum(v for k, v in coll.items() if k != "count"))

    # cost/hlo describe ONE device's program: per-chip denominators.
    t_c = flops / PEAK_FLOPS_BF16
    t_m = nbytes / HBM_BW
    t_x = coll_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    model_flops_dev = model_flops / max(chips, 1)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_bytes=coll_bytes,
        collective_breakdown=coll,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops_dev / flops) if flops else 0.0,
        bytes_per_device=bytes_per_device,
        notes=notes,
    )


def model_flops_estimate(cfg, shape_cfg) -> float:
    """6·N·D (training) / 2·N·D (inference) with N = active params."""
    from repro.core.comm import backbone_param_count

    n = backbone_param_count(cfg)
    if cfg.family == "moe":
        m = cfg.moe
        expert_total = cfg.n_layers * m.n_experts * 3 * cfg.d_model * cfg.d_ff
        expert_active = cfg.n_layers * m.top_k * 3 * cfg.d_model * cfg.d_ff
        n = n - expert_total + expert_active
    tokens = shape_cfg.global_batch * (shape_cfg.seq_len if shape_cfg.kind != "decode" else 1)
    mult = 6.0 if shape_cfg.kind == "train" else 2.0
    return mult * n * tokens
