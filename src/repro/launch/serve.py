"""Multi-tenant serving driver (``python -m repro.launch.serve``).

The deployment half of FedNano: ONE frozen backbone serves many tenants,
each tenant being a federated client whose tuned NanoAdapters are
hot-swapped into the engine's adapter bank. Requests from different
tenants with different prompt lengths are continuously batched — admission
prefills into a free decode slot, then every engine step decodes all
occupied slots in one fixed-shape jitted call with per-row grouped-LoRA
adapter selection, so mixed traffic never recompiles.

Adapters come from ``--ckpt-root`` (a directory of per-tenant federated
checkpoints: ``<root>/<tenant>`` as a ``save_server_checkpoint`` dir or a
bare ``.npz``) or, without one, are synthesized per tenant so the
multi-tenant path is exercisable standalone. ``--naive`` cross-checks the
engine against the one-request-at-a-time loop (the pre-engine serving
path) and reports token parity + speedup.

On a real deployment the same prefill/decode step functions lower onto the
production mesh (repro.launch.dryrun proves decode_32k/long_500k for every
arch); here they run on host CPU at smoke scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config, list_archs
from repro.core import adapters as nano
from repro.models import model as backbone_lib
from repro.models.vision_stub import num_patches
from repro.serving import (
    Request,
    ServingEngine,
    checkpoint_adapter_loader,
    generate_naive,
)


def synth_tenant_adapters(key, cfg, tenants):
    """Deterministic non-identity adapter sets, one per tenant name."""
    out = {}
    for i, t in enumerate(tenants):
        ad = nano.init_nanoedge(jax.random.fold_in(key, 100 + i), cfg)
        ad = jax.tree.map(
            lambda a, j=i: jax.random.normal(
                jax.random.fold_in(key, 1000 + j * 7 + a.size % 97),
                a.shape, a.dtype) * 0.05,
            ad)
        out[t] = ad
    return out


def make_requests(cfg, tenants, n_requests, prefill_len, gen_tokens, seed):
    """Mixed workload: tenants round-robin (every 5th request tenantless),
    prompt lengths cycling through [2, prefill_len]."""
    rng = np.random.default_rng(seed)
    m = num_patches(cfg) if cfg.frontend_dim else 0
    reqs = []
    for i in range(n_requests):
        tenant = None if (i % 5 == 4) else tenants[i % len(tenants)]
        length = 2 + (i * 3) % (prefill_len - 1)
        patches = (rng.standard_normal((m, cfg.frontend_dim)).astype(np.float32)
                   if cfg.frontend_dim else None)
        reqs.append(Request(
            rid=i, tenant=tenant,
            prompt=rng.integers(0, cfg.vocab_size, length).astype(np.int32),
            patches=patches, max_new_tokens=gen_tokens))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llava-1.5-7b", choices=list_archs())
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (page pool size)")
    ap.add_argument("--adapter-slots", type=int, default=8,
                    help="adapter bank size (LRU over tenants)")
    ap.add_argument("--ckpt-root", default=None,
                    help="directory of per-tenant federated checkpoints; "
                         "tenant names are the entries inside")
    ap.add_argument("--pallas-grouped", action="store_true",
                    help="run the grouped-LoRA Pallas kernel (interpret "
                         "mode on CPU) instead of the jnp reference")
    ap.add_argument("--naive", action="store_true",
                    help="also run the one-request-at-a-time loop, check "
                         "token parity, and report the speedup")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    cfg = get_smoke_config(args.arch)
    backbone = backbone_lib.init_backbone(key, cfg)

    if args.ckpt_root:
        import os

        tenant_names = sorted(
            os.path.splitext(e)[0] for e in os.listdir(args.ckpt_root))
        if not tenant_names:
            raise SystemExit(f"--ckpt-root {args.ckpt_root!r} is empty")
        tenant_names = tenant_names[: args.tenants]
        loader = checkpoint_adapter_loader(cfg, args.ckpt_root)
        adapters_by_tenant = {t: loader(t) for t in tenant_names}
        print(f"serving {len(tenant_names)} tenants from {args.ckpt_root}")
    else:
        tenant_names = [f"tenant{i}" for i in range(args.tenants)]
        adapters_by_tenant = synth_tenant_adapters(key, cfg, tenant_names)
        loader = adapters_by_tenant.__getitem__
        print(f"serving {len(tenant_names)} synthetic tenants "
              "(no --ckpt-root)")

    reqs = make_requests(cfg, tenant_names, args.requests, args.prefill_len,
                         args.gen_tokens, args.seed)
    engine = ServingEngine(
        cfg, backbone, max_slots=args.slots, prefill_len=args.prefill_len,
        max_new_tokens=args.gen_tokens, adapter_slots=args.adapter_slots,
        adapter_loader=loader, use_pallas_grouped=args.pallas_grouped)

    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done.values())
    print(f"arch={args.arch} engine: {len(reqs)} requests, {n_tok} tokens "
          f"in {dt:.2f}s ({n_tok / dt:.1f} tok/s on 1 CPU core) | "
          f"occupancy {engine.mean_occupancy():.2f}/{args.slots} | "
          f"adapter cache {engine.cache.stats()}")
    for rid in sorted(done)[:4]:
        c = done[rid]
        print(f"  req {rid} [{c.tenant or 'base'}]: {c.tokens}")

    if args.naive:
        t0 = time.time()
        ref = generate_naive(cfg, backbone, reqs, adapters_by_tenant)
        dt_naive = time.time() - t0
        mismatch = [r.rid for r in reqs if done[r.rid].tokens != ref[r.rid].tokens]
        if mismatch:
            raise SystemExit(f"TOKEN MISMATCH vs naive loop: rids {mismatch}")
        print(f"naive loop: {n_tok} tokens in {dt_naive:.2f}s "
              f"({n_tok / dt_naive:.1f} tok/s) — token parity OK, "
              f"engine speedup {dt_naive / dt:.2f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
