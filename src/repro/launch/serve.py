"""Split-serving driver (``python -m repro.launch.serve``).

Serves batched VQA requests through the FedNano split: client-side NanoEdge
(embed + connect + adapt) feeding the server-hosted frozen backbone's
prefill + greedy decode loop. Loads tuned adapters from a checkpoint
directory if given (produced by repro.launch.train), else serves with
freshly-initialized (identity) adapters.

On a real deployment the same prefill/decode step functions lower onto the
production mesh (repro.launch.dryrun proves decode_32k/long_500k for every
arch); here they run on host CPU at smoke scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, list_archs
from repro.core import adapters as nano
from repro.data import SyntheticVQA, examples_to_batches
from repro.models import model as backbone_lib


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llava-1.5-7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--ckpt", default=None, help="server checkpoint dir (adapters)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    cfg = get_smoke_config(args.arch)
    backbone = backbone_lib.init_backbone(key, cfg)
    adapters = nano.init_nanoedge(jax.random.fold_in(key, 1), cfg)
    if args.ckpt:
        from repro.checkpoint import load_pytree
        import os

        backbone = load_pytree(os.path.join(args.ckpt, "backbone.npz"), backbone)
        adapters = load_pytree(os.path.join(args.ckpt, "global_adapters.npz"), adapters)
        print(f"loaded adapters + backbone from {args.ckpt}")

    gen = SyntheticVQA(
        vocab_size=cfg.vocab_size, seq_len=24,
        frontend_dim=cfg.frontend_dim,
        n_patches=(cfg.enc_seq_len if cfg.family == "audio"
                   else (8 if cfg.frontend_dim else 0)) or 8,
    )
    batch = examples_to_batches(gen.generate(args.batch, seed=args.seed), args.batch)[0]

    embeds, positions, _, _, enc = nano.nanoedge_forward(cfg, backbone, adapters, batch)
    capacity = embeds.shape[1] + args.gen_tokens + 1

    @jax.jit
    def prefill(embeds, positions, enc):
        state, hidden = backbone_lib.prefill(cfg, backbone, embeds, positions,
                                             capacity, enc_embeds=enc)
        return state, backbone_lib.logits(cfg, backbone, hidden[:, -1:, :])

    @jax.jit
    def decode(state, emb, pos):
        return backbone_lib.decode_step(cfg, backbone, emb, state, pos)

    t0 = time.time()
    state, last = prefill(embeds, positions, enc)
    tok = jnp.argmax(last[:, 0], axis=-1)
    out = [tok]
    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha)
    for step in range(args.gen_tokens - 1):
        pos = jnp.int32(embeds.shape[1] + step)
        emb = backbone_lib.embed_tokens(cfg, backbone, tok[:, None])
        if "text" in adapters:
            emb = nano.nano_adapter_apply(adapters["text"], emb, **kw)
        lg, state = decode(state, emb, pos)
        tok = jnp.argmax(lg[:, 0], axis=-1)
        out.append(tok)
    toks = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"arch={args.arch} served {args.batch} requests × {args.gen_tokens} tokens "
          f"in {dt:.2f}s ({args.batch*args.gen_tokens/dt:.1f} tok/s on 1 CPU core)")
    for i in range(min(args.batch, 4)):
        print(f"  req {i}: {[int(t) for t in toks[i]]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
