"""Pytree utilities used across the framework.

Everything here is a thin, well-tested wrapper over ``jax.tree_util`` —
we build on pure JAX (no flax/optax in this environment), so the optimizer,
aggregation, and checkpoint layers all speak "pytree of arrays".
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    # math.prod over the shape tuple, not np.prod: the engines call this
    # per client per round, and np.prod's ufunc dispatch is ~100x slower
    # on a small tuple than the C-level math.prod
    return sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (uses each leaf's dtype itemsize)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        itemsize = np.dtype(x.dtype).itemsize
        total += math.prod(x.shape) * itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_dot(a, b):
    """Sum of elementwise products across two pytrees (a scalar)."""
    leaves = jax.tree.map(lambda x, y: jnp.sum(x * y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(tree):
    return tree_dot(tree, tree)


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0.

    Host-resident leaves (numpy, as produced by the vmap engine's unstack)
    take a C-level ``np.stack`` + one transfer instead of a K-operand device
    op — at 10k clients the difference is the aggregation's wall-clock.
    Tracers and device arrays fall through to ``jnp.stack`` unchanged.
    """

    def _stack(*xs):
        if all(type(x) is np.ndarray for x in xs):
            return jnp.asarray(np.stack(xs, axis=0))
        return jnp.stack(xs, axis=0)

    return jax.tree.map(_stack, *trees)


def tree_unstack(tree, n: int):
    """Inverse of :func:`tree_stack` — returns a list of ``n`` pytrees."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_weighted_sum(trees, weights):
    """Σ_k w_k · tree_k, accumulated in float32 (streaming-merge building
    block: callers fold fixed-size chunks so memory stays O(chunk))."""
    w = jnp.asarray(weights, jnp.float32)
    stacked = tree_stack(trees)
    return jax.tree.map(
        lambda s: jnp.tensordot(w, s.astype(jnp.float32), axes=1), stacked
    )


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(
        lambda x, y: bool(np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)),
        a,
        b,
    )
    return all(jax.tree_util.tree_leaves(oks))


def fmt_params(n: int) -> str:
    if n >= 1e9:
        return f"{n / 1e9:.2f}B"
    if n >= 1e6:
        return f"{n / 1e6:.2f}M"
    if n >= 1e3:
        return f"{n / 1e3:.2f}K"
    return str(n)


def fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if n < 1024 or unit == "TiB":
            return f"{n:.2f}{unit}" if unit != "B" else f"{n}B"
        n /= 1024
    return f"{n}B"
