"""Jitted public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention as _fa


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    block_q=128, block_k=512, interpret=False):
    return _fa(
        q, k, v,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
