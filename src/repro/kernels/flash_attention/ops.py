"""Jitted public wrapper for the flash-attention kernel, with a custom VJP.

Pallas calls are not differentiable in this JAX build, so the backward pass
is the standard flash-attention recomputation: the forward kernel saves the
per-row logsumexp L, and the backward rebuilds the probabilities blockwise
from p = exp(s − L) instead of differentiating through a softmax —

    dv = pᵀ·do
    ds = p ∘ (do·vᵀ − rowsum(do ∘ o))        (the "D-trick": no p saved)
    dq = scale · ds·k,   dk = scale · dsᵀ·q

with the softcap chain factor (1 − tanh²) folded into ds and GQA K/V grads
summed over each head group. This is an independent implementation of the
gradient (saved-LSE + D-trick vs autodiff-through-softmax), so the parity
check against ``jax.grad`` of the jnp ref in tests/kernel_harness.py is a
real differential test of both the kernel's LSE and the backward math.

Block sizes: ``block_q=None`` / ``block_k=None`` consult the tuning table
(``repro.kernels.tuning``); explicit values pass through untouched.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention.flash_attention import NEG_INF, flash_attention as _fa


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa_vjp(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    return _fa(q, k, v, causal=causal, window=window, softcap=softcap,
               block_q=block_q, block_k=block_k, interpret=interpret)


def _fa_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    out, lse = _fa(q, k, v, causal=causal, window=window, softcap=softcap,
                   block_q=block_q, block_k=block_k, interpret=interpret,
                   return_lse=True)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, softcap, block_q, block_k, interpret, res, g):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    Sk = k.shape[1]
    group = H // Hkv

    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    gf = g.astype(jnp.float32)
    of = out.astype(jnp.float32)
    scale = D**-0.5

    u = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    if softcap and softcap > 0.0:
        t = jnp.tanh(u / softcap)
        s = t * softcap
        dfac = 1.0 - t * t
    else:
        s = u
        dfac = None

    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)

    # p from the kernel's saved LSE; fully-masked rows carry lse ~ NEG_INF
    lse_h = jnp.moveaxis(lse, 1, 2)                           # (B, H, Sq)
    live = (lse_h > NEG_INF / 2)[..., None]                   # (B, H, Sq, 1)
    p = jnp.where(mask[None, None] & live, jnp.exp(s - lse_h[..., None]), 0.0)

    dv_h = jnp.einsum("bhqk,bqhd->bkhd", p, gf)
    dp = jnp.einsum("bqhd,bkhd->bhqk", gf, vf)
    drow = jnp.moveaxis(jnp.sum(gf * of, axis=-1), 1, 2)      # (B, H, Sq)
    ds = p * (dp - drow[..., None])
    if dfac is not None:
        ds = ds * dfac
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kf) * scale
    dk_h = jnp.einsum("bhqk,bqhd->bkhd", ds, qf) * scale

    if group > 1:
        dk_h = dk_h.reshape(B, Sk, Hkv, group, D).sum(axis=3)
        dv_h = dv_h.reshape(B, Sk, Hkv, group, D).sum(axis=3)
    return dq.astype(q.dtype), dk_h.astype(k.dtype), dv_h.astype(v.dtype)


_fa_vjp.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def _fa_jit(q, k, v, *, causal, window, softcap, block_q, block_k, interpret):
    return _fa_vjp(q, k, v, causal, window, softcap, block_q, block_k, interpret)


def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    softcap: float = 0.0, block_q: int = None,
                    block_k: int = None, interpret: bool = False):
    """q (B, Sq, H, D); k, v (B, Sk, Hkv, D). Differentiable in (q, k, v).

    ``block_q``/``block_k`` = None → tuning table (clamped to the sequence
    lengths inside the kernel, so small shapes match the historical
    (128, 512) defaults exactly).
    """
    if block_q is None or block_k is None:
        bq, bk = tuning.flash_blocks(q.shape[1], k.shape[1], q.shape[-1])
        block_q = bq if block_q is None else block_q
        block_k = bk if block_k is None else block_k
    return _fa_jit(q, k, v, causal=causal, window=window, softcap=softcap,
                   block_q=block_q, block_k=block_k, interpret=interpret)
