"""Blockwise flash attention — Pallas TPU kernel.

TPU-native online-softmax attention (the SDPA replacement, DESIGN.md §3):

  * grid (B, H, nQ, nK) — the nK axis is innermost and sequential on a TPU
    core, so the running max/denominator/accumulator live in VMEM scratch
    and carry across k-steps; they are initialized at k==0 and the output
    tile is written once at the final k-step (classic two-pass-free form).
  * GQA-aware: K/V BlockSpecs index-map head h -> h // (H // Hkv), so a KV
    head group is loaded into VMEM ONCE per Q-head — on real hardware this
    is the bandwidth win over head-repeated SDPA.
  * causal + sliding-window masks are applied per tile from 2D iotas;
    grok-style tanh softcap optionally applied pre-mask.
  * block sizes default to (128, 512) — MXU-aligned (multiples of 8×128
    lanes) and small enough that q, k, v, acc tiles fit VMEM at head_dim 256.

Numerics: all softmax state in fp32 scratch regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int], softcap: float,
            block_q: int, block_k: int, q_offset: int, n_k: int, kv_len: int):
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)   # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)   # (bk, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    qb = pl.program_id(2)
    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # padded keys (kpos >= kv_len) must never reach the softmax denominator;
    # the causal mask happens to cover them when Sq == Sk, but bidirectional
    # or cross-attention shapes need the explicit bound
    mask = kpos < kv_len
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be exp(0)=1)
    p = jnp.exp(jnp.where(m_new <= NEG_INF / 2, NEG_INF, s - m_new))
    alpha = jnp.exp(
        jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_new)
    )                                             # (bq, 1)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(kb == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)
        # per-row logsumexp (flash residual for the backward pass); rows
        # that never saw an unmasked key keep m == NEG_INF as the marker
        lse_ref[0, :, 0] = (m_scr[...] + jnp.log(denom))[:, 0]


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 512,
    interpret: bool = False,
    return_lse: bool = False,
):
    """q (B, Sq, H, D); k, v (B, Sk, Hkv, D), H % Hkv == 0. Returns (B, Sq, H, D).

    Query i has absolute position (Sk - Sq) + i (decode/prefill alignment).
    With ``return_lse`` also returns the per-row logsumexp (B, Sq, H) — the
    flash residual the custom VJP in ``ops.py`` rebuilds probabilities from.
    """
    B, Sq, H, D = q.shape
    Bk, Sk, Hkv, Dk = k.shape
    assert (B, D) == (Bk, Dk) and H % Hkv == 0
    group = H // Hkv

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sqp, Skp = q.shape[1], k.shape[1]
    n_q, n_k = Sqp // bq, Skp // bk
    q_offset = Sk - Sq

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=D**-0.5,
            causal=causal,
            window=window,
            softcap=softcap,
            block_q=bq,
            block_k=bk,
            q_offset=q_offset,
            n_k=n_k,
            kv_len=Sk,
        ),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, g=group: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, i, j, g=group: (b, j, h // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, h, i, j: (b, i, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sqp, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, Sqp, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    out, lse = out
    if pad_q:
        out, lse = out[:, :Sq], lse[:, :Sq]
    return (out, lse) if return_lse else out
