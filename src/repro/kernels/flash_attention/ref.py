"""Pure-jnp oracle for the blockwise flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: float = 0.0, n_kv: Optional[int] = None):
    """q (B, Sq, H, D); k, v (B, Sk, Hkv, D) with H % Hkv == 0 (GQA).

    Returns (B, Sq, H, D). Query position i is aligned so that the LAST query
    attends to the LAST key (q_offset = Sk - Sq).
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    Sk = k.shape[1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * (D**-0.5)
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
