"""Pallas TPU kernels for the compute hot-spots (validated interpret=True on CPU).

Each kernel ships three files (per the repo convention):
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     — jitted public wrapper
    ref.py     — pure-jnp oracle (tests assert_allclose against it)

Kernels:
    lora/            fused NanoAdapter residual  y = x + s·(x·A)·B
    fisher_merge/    Eq.-1 K-client Fisher-weighted merge (memory-bound)
    flash_attention/ blockwise online-softmax attention (GQA/SWA/softcap)
    ssd_scan/        Mamba2 chunked SSD scan (state carried in VMEM scratch)
"""
from repro.kernels import fisher_merge, flash_attention, lora, ssd_scan

__all__ = ["fisher_merge", "flash_attention", "lora", "ssd_scan"]
