"""Pure-jnp oracle for the Fisher-merge kernel (paper Eq. 1, elementwise)."""
from __future__ import annotations

import jax.numpy as jnp


def fisher_merge(theta, fisher, weights, *, eps: float = 1e-8):
    """theta/fisher (K, N); weights (K,) -> merged (N,).

    out = Σ_k w_k F_k θ_k / (Σ_k w_k F_k + eps)
    """
    t = theta.astype(jnp.float32)
    f = fisher.astype(jnp.float32)
    w = weights.astype(jnp.float32)[:, None]
    num = jnp.sum(w * f * t, axis=0)
    den = jnp.sum(w * f, axis=0)
    return (num / (den + eps)).astype(theta.dtype)
