"""Pure-jnp oracle for the Fisher-merge kernel (paper Eq. 1, elementwise)."""
from __future__ import annotations

import jax.numpy as jnp


def fisher_merge(theta, fisher, weights, *, eps: float = 1e-8):
    """theta/fisher (K, N); weights (K,) -> merged (N,).

    out = Σ_k w_k F_k θ_k / (Σ_k w_k F_k + eps)
    """
    t = theta.astype(jnp.float32)
    f = fisher.astype(jnp.float32)
    w = weights.astype(jnp.float32)[:, None]
    num = jnp.sum(w * f * t, axis=0)
    den = jnp.sum(w * f, axis=0)
    return (num / (den + eps)).astype(theta.dtype)


def fisher_fold(num, den, theta, fisher, w):
    """Streaming fold step: one client's (θ, F, w) into the running sums.

    num/den (N,) float32; folding every client then calling
    :func:`fisher_finalize` reproduces :func:`fisher_merge` up to f32
    summation order.
    """
    wf = jnp.float32(w) * fisher.astype(jnp.float32)
    return num + wf * theta.astype(jnp.float32), den + wf


def fisher_finalize(num, den, *, eps: float = 1e-8, dtype=jnp.float32):
    """num / (den + eps) with the accumulators' f32 carried to the end."""
    return (num / (den + eps)).astype(dtype)
