"""Fisher-merge Pallas TPU kernel (paper Eq. 1).

Purely memory-bound: 3 reads (θ, F per client) + 1 write per element, zero
reuse — the roofline is HBM bandwidth. The kernel streams (K, block_n) tiles
through VMEM and reduces over the client axis K in-register, so each element
of θ/F is read exactly once (a fused jnp expression would also manage this
via XLA fusion for small K; the kernel guarantees it for the K≈100s regime
of cross-device federated fleets and keeps the weighted-reduce in fp32
regardless of storage dtype).

Block shape: (K, 1024) f32 tiles — K up to ~512 clients × 4 KiB lanes stays
well under VMEM; N is padded to the lane multiple by the compiler.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, f_ref, w_ref, o_ref, *, eps: float):
    t = t_ref[...].astype(jnp.float32)   # (K, bn)
    f = f_ref[...].astype(jnp.float32)   # (K, bn)
    w = w_ref[...].astype(jnp.float32)   # (K, 1)
    wf = w * f
    num = jnp.sum(wf * t, axis=0)        # (bn,)
    den = jnp.sum(wf, axis=0)
    o_ref[...] = ((num / (den + eps)).astype(o_ref.dtype))[None, :]


def _fold_kernel(num_ref, den_ref, t_ref, f_ref, w_ref, num_out, den_out):
    w = w_ref[0, 0]
    wf = w * f_ref[...].astype(jnp.float32)
    num_out[...] = num_ref[...] + wf * t_ref[...].astype(jnp.float32)
    den_out[...] = den_ref[...] + wf


def fisher_fold_2d(num, den, theta, fisher, w, *, block_n: int = 1024,
                   interpret: bool = False):
    """One streaming-merge fold step: (num', den') = (num + w·F·θ, den + w·F).

    num/den (N,) float32 running sums; theta/fisher (N,) any dtype; w scalar.
    The streaming counterpart of :func:`fisher_merge_2d` — the server folds
    one client at a time, so no (K, N) stack ever exists. Same roofline
    character (pure bandwidth, zero reuse); the fused kernel reads each of
    the four streams once per element and writes two.
    """
    N = num.shape[0]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        zpad = lambda a: jnp.pad(a.reshape(1, N), ((0, 0), (0, pad)))
    else:
        zpad = lambda a: a.reshape(1, N)
    num2, den2 = zpad(num), zpad(den)
    t2, f2 = zpad(theta), zpad(fisher)
    Np = num2.shape[1]
    w2 = jnp.asarray(w, jnp.float32).reshape(1, 1)

    num_new, den_new = pl.pallas_call(
        _fold_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Np), jnp.float32),
            jax.ShapeDtypeStruct((1, Np), jnp.float32),
        ],
        interpret=interpret,
    )(num2, den2, t2, f2, w2)
    num_new, den_new = num_new[0], den_new[0]
    if pad:
        num_new, den_new = num_new[:N], den_new[:N]
    return num_new, den_new


def fisher_merge_2d(theta, fisher, weights, *, eps: float = 1e-8,
                    block_n: int = 1024, interpret: bool = False):
    """theta/fisher (K, N); weights (K,) -> (N,)."""
    K, N = theta.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
        fisher = jnp.pad(fisher, ((0, 0), (0, pad)))
    Np = theta.shape[1]
    w2 = weights.reshape(K, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), theta.dtype),
        interpret=interpret,
    )(theta, fisher, w2)
    out = out[0]
    return out[:N] if pad else out
