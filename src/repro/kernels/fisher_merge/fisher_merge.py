"""Fisher-merge Pallas TPU kernel (paper Eq. 1).

Purely memory-bound: 3 reads (θ, F per client) + 1 write per element, zero
reuse — the roofline is HBM bandwidth. The kernel streams (K, block_n) tiles
through VMEM and reduces over the client axis K in-register, so each element
of θ/F is read exactly once (a fused jnp expression would also manage this
via XLA fusion for small K; the kernel guarantees it for the K≈100s regime
of cross-device federated fleets and keeps the weighted-reduce in fp32
regardless of storage dtype).

Block shape: (K, 1024) f32 tiles — K up to ~512 clients × 4 KiB lanes stays
well under VMEM; N is padded to the lane multiple by the compiler.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(t_ref, f_ref, w_ref, o_ref, *, eps: float):
    t = t_ref[...].astype(jnp.float32)   # (K, bn)
    f = f_ref[...].astype(jnp.float32)   # (K, bn)
    w = w_ref[...].astype(jnp.float32)   # (K, 1)
    wf = w * f
    num = jnp.sum(wf * t, axis=0)        # (bn,)
    den = jnp.sum(wf, axis=0)
    o_ref[...] = ((num / (den + eps)).astype(o_ref.dtype))[None, :]


def fisher_merge_2d(theta, fisher, weights, *, eps: float = 1e-8,
                    block_n: int = 1024, interpret: bool = False):
    """theta/fisher (K, N); weights (K,) -> (N,)."""
    K, N = theta.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
        fisher = jnp.pad(fisher, ((0, 0), (0, pad)))
    Np = theta.shape[1]
    w2 = weights.reshape(K, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), theta.dtype),
        interpret=interpret,
    )(theta, fisher, w2)
    out = out[0]
    return out[:N] if pad else out
