"""Jitted public wrapper for the Fisher-merge kernel (arbitrary leaf shapes)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.fisher_merge.fisher_merge import fisher_merge_2d


@functools.partial(jax.jit, static_argnames=("eps", "block_n", "interpret"))
def fisher_merge(theta, fisher, weights, *, eps: float = 1e-8,
                 block_n: int = 1024, interpret: bool = False):
    """theta/fisher (K, ...) stacked client leaves; weights (K,).

    Returns the merged leaf of shape (...).
    """
    k = theta.shape[0]
    rest = theta.shape[1:]
    t = theta.reshape(k, -1)
    f = fisher.reshape(k, -1)
    out = fisher_merge_2d(t, f, weights, eps=eps, block_n=block_n, interpret=interpret)
    return out.reshape(rest)
