"""Jitted public wrappers for the Fisher-merge kernels (arbitrary leaf shapes).

Two forms of paper Eq. 1:

  * ``fisher_merge``      — materializing: takes the (K, ...) client stack.
  * ``fisher_fold``       — streaming: folds ONE client's (θ, F, w) into
    running f32 (num, den) sums, so the server never holds a (K, ...) stack;
    ``repro.strategies`` builds FedNano's ``agg_stream_*`` hooks on it.

``block_n=None`` consults the tuning table (numerics-free: element blocks
are independent).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import tuning
from repro.kernels.fisher_merge.fisher_merge import fisher_fold_2d, fisher_merge_2d


@functools.partial(jax.jit, static_argnames=("eps", "block_n", "interpret"))
def _fisher_merge_jit(theta, fisher, weights, *, eps, block_n, interpret):
    k = theta.shape[0]
    rest = theta.shape[1:]
    t = theta.reshape(k, -1)
    f = fisher.reshape(k, -1)
    out = fisher_merge_2d(t, f, weights, eps=eps, block_n=block_n, interpret=interpret)
    return out.reshape(rest)


def fisher_merge(theta, fisher, weights, *, eps: float = 1e-8,
                 block_n: int = None, interpret: bool = False):
    """theta/fisher (K, ...) stacked client leaves; weights (K,).

    Returns the merged leaf of shape (...). ``block_n=None`` → tuning table.
    """
    if block_n is None:
        n = 1
        for s in theta.shape[1:]:
            n *= int(s)
        block_n = tuning.fisher_block_n(theta.shape[0], n)
    return _fisher_merge_jit(theta, fisher, weights, eps=eps, block_n=block_n,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _fisher_fold_jit(num, den, theta, fisher, w, *, block_n, interpret):
    shape = theta.shape
    num_new, den_new = fisher_fold_2d(
        num.reshape(-1), den.reshape(-1), theta.reshape(-1), fisher.reshape(-1),
        w, block_n=block_n, interpret=interpret)
    return num_new.reshape(shape), den_new.reshape(shape)


def fisher_fold(num, den, theta, fisher, w, *, block_n: int = None,
                interpret: bool = False):
    """Streaming fold of one client leaf: returns (num + w·F·θ, den + w·F).

    num/den are float32 running sums shaped like the leaf; ``w`` is a scalar
    (jnp or python). O(1) server memory in the client count.
    """
    if block_n is None:
        n = 1
        for s in theta.shape:
            n *= int(s)
        block_n = tuning.fisher_block_n(1, n)
    return _fisher_fold_jit(num, den, theta, fisher, w, block_n=block_n,
                            interpret=interpret)
