"""Pure-jnp oracle for the fused NanoAdapter (LoRA) kernel."""
from __future__ import annotations

import jax.numpy as jnp


def lora_residual(x, down, up, *, scale: float):
    """y = x + scale · (x @ down) @ up.

    x (..., D); down (D, r); up (r, D).
    """
    h = x.astype(jnp.float32) @ down.astype(jnp.float32)
    y = h @ up.astype(jnp.float32)
    return (x.astype(jnp.float32) + scale * y).astype(x.dtype)


def grouped_lora_residual(x, down, up, idx, *, scale: float):
    """Per-row adapter selection against a stacked bank (serving oracle).

    x (..., D); down (N, D, r); up (N, r, D); idx (...) int32 — the adapter
    id of each row. idx < 0 leaves the row untouched (identity adapter).
    """
    n = down.shape[0]
    safe = jnp.clip(idx, 0, n - 1)
    a = jnp.take(down, safe, axis=0).astype(jnp.float32)   # (..., D, r)
    b = jnp.take(up, safe, axis=0).astype(jnp.float32)     # (..., r, D)
    h = jnp.einsum("...d,...dr->...r", x.astype(jnp.float32), a)
    y = jnp.einsum("...r,...rd->...d", h, b)
    y = jnp.where((idx >= 0)[..., None], y, 0.0)
    return (x.astype(jnp.float32) + scale * y).astype(x.dtype)
