"""Pure-jnp oracle for the fused NanoAdapter (LoRA) kernel."""
from __future__ import annotations

import jax.numpy as jnp


def lora_residual(x, down, up, *, scale: float):
    """y = x + scale · (x @ down) @ up.

    x (..., D); down (D, r); up (r, D).
    """
    h = x.astype(jnp.float32) @ down.astype(jnp.float32)
    y = h @ up.astype(jnp.float32)
    return (x.astype(jnp.float32) + scale * y).astype(x.dtype)
