"""Jitted public wrapper for the fused LoRA kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lora.lora import grouped_lora_residual_2d, lora_residual_2d


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def lora_residual(x, down, up, *, scale: float, block_t: int = 256, interpret: bool = False):
    """y = x + scale·(x·down)·up for x of any leading shape (..., D)."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = lora_residual_2d(flat, down, up, scale=scale, block_t=block_t, interpret=interpret)
    return out.reshape(*lead, d)


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def grouped_lora_residual(x, down, up, idx, *, scale: float, block_t: int = 256,
                          interpret: bool = False):
    """Multi-tenant LoRA: per-row adapter ids into a stacked bank.

    x (..., D); down (N, D, r); up (N, r, D); idx (...) int32 aligned with
    x's leading shape (idx < 0 = identity row).
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    fidx = idx.reshape(-1)
    out = grouped_lora_residual_2d(flat, down, up, fidx, scale=scale,
                                   block_t=block_t, interpret=interpret)
    return out.reshape(*lead, d)
