"""Jitted public wrapper for the fused LoRA kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lora.lora import lora_residual_2d


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def lora_residual(x, down, up, *, scale: float, block_t: int = 256, interpret: bool = False):
    """y = x + scale·(x·down)·up for x of any leading shape (..., D)."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = lora_residual_2d(flat, down, up, scale=scale, block_t=block_t, interpret=interpret)
    return out.reshape(*lead, d)
