"""Jitted public wrappers for the fused LoRA kernels, with a custom VJP.

Pallas calls are not differentiable in this JAX build, so ``lora_residual``
carries a hand-written backward: for y = x + s·(x·A)·B,

    dx = g + s·(g·Bᵀ)·Aᵀ        — the forward kernel with transposed adapters
    dA = s · xᵀ·(g·Bᵀ)
    dB = s · (x·A)ᵀ·g

dx reuses the Pallas kernel (it IS a LoRA residual over g with the adapter
pair (Bᵀ, Aᵀ)); the adapter grads are adapter-sized f32 matmuls, too small
to be worth a kernel. Gradient parity vs ``jax.grad`` of the jnp ref is
pinned by the kernel harness (tests/kernel_harness.py).

Block sizes: ``block_t=None`` consults the tuning table
(``repro.kernels.tuning``); explicit values pass through untouched. Token
blocking tiles independent rows, so every block size is bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.lora.lora import grouped_lora_residual_2d, lora_residual_2d


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _lora_2d(x, down, up, scale, block_t, interpret):
    return lora_residual_2d(x, down, up, scale=scale, block_t=block_t,
                            interpret=interpret)


def _lora_2d_fwd(x, down, up, scale, block_t, interpret):
    out = lora_residual_2d(x, down, up, scale=scale, block_t=block_t,
                           interpret=interpret)
    return out, (x, down, up)


def _lora_2d_bwd(scale, block_t, interpret, res, g):
    x, down, up = res
    # dx through the same kernel: g + s·(g·Bᵀ)·Aᵀ.
    dx = lora_residual_2d(g, jnp.transpose(up), jnp.transpose(down),
                          scale=scale, block_t=block_t, interpret=interpret)
    gf = g.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    gb = gf @ jnp.transpose(up).astype(jnp.float32)          # (T, r)
    d_down = scale * (jnp.transpose(xf) @ gb)                # (D, r)
    h = xf @ down.astype(jnp.float32)                        # (T, r)
    d_up = scale * (jnp.transpose(h) @ gf)                   # (r, D)
    return dx.astype(x.dtype), d_down.astype(down.dtype), d_up.astype(up.dtype)


_lora_2d.defvjp(_lora_2d_fwd, _lora_2d_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def _lora_residual_jit(x, down, up, *, scale, block_t, interpret):
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    out = _lora_2d(flat, down, up, scale, block_t, interpret)
    return out.reshape(*lead, d)


def lora_residual(x, down, up, *, scale: float, block_t: int = None,
                  interpret: bool = False):
    """y = x + scale·(x·down)·up for x of any leading shape (..., D).

    Differentiable in (x, down, up). ``block_t=None`` → tuning table.
    """
    if block_t is None:
        t = 1
        for s in x.shape[:-1]:
            t *= int(s)
        block_t = tuning.lora_block_t(t, x.shape[-1], down.shape[-1])
    return _lora_residual_jit(x, down, up, scale=scale, block_t=block_t,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "interpret"))
def _grouped_jit(x, down, up, idx, *, scale, block_t, interpret):
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    fidx = idx.reshape(-1)
    out = grouped_lora_residual_2d(flat, down, up, fidx, scale=scale,
                                   block_t=block_t, interpret=interpret)
    return out.reshape(*lead, d)


def grouped_lora_residual(x, down, up, idx, *, scale: float, block_t: int = None,
                          interpret: bool = False):
    """Multi-tenant LoRA: per-row adapter ids into a stacked bank.

    x (..., D); down (N, D, r); up (N, r, D); idx (...) int32 aligned with
    x's leading shape (idx < 0 = identity row). ``block_t=None`` → tuning
    table (numerics-free either way: rows are tiled independently).
    """
    if block_t is None:
        t = 1
        for s in x.shape[:-1]:
            t *= int(s)
        block_t = tuning.lora_block_t(t, x.shape[-1], down.shape[-1])
    return _grouped_jit(x, down, up, idx, scale=scale, block_t=block_t,
                        interpret=interpret)
