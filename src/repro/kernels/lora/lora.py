"""Fused NanoAdapter (LoRA) Pallas TPU kernel.

Computes y = x + scale·(x·A)·B without materializing the rank-r intermediate
in HBM: each grid step loads one (block_t, D) tile of tokens into VMEM, both
adapter matrices stay VMEM-resident across the whole grid (A: D×r, B: r×D —
≤ 4 MiB even at D=8192, r=64), and the two matmuls + residual add fuse into
one VMEM-round-trip. MXU alignment: block_t multiple of 8, D and r padded by
the compiler to lane multiples (r=64 is already half a lane tile; fine).

Why a kernel at all: at rank 64 the adapter matmuls are heavily
memory-bound (arithmetic intensity ≈ r ≈ 64 FLOP/B vs the MXU's ~240
FLOP/B break-even at bf16); the win is avoiding a second HBM pass over x
and the (T, r) intermediate, not FLOPs.

The grouped variant (``grouped_lora_residual_2d``) is the multi-tenant
serving form: every row carries an adapter index into a stacked
(N, D, r)/(N, r, D) bank, so one kernel launch serves a mixed-tenant batch
(S-LoRA / punica idiom; repro.serving builds its decode step on it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32)          # (bt, D)
    a = a_ref[...].astype(jnp.float32)          # (D, r)
    b = b_ref[...].astype(jnp.float32)          # (r, D)
    h = jnp.dot(x, a, preferred_element_type=jnp.float32)
    y = jnp.dot(h, b, preferred_element_type=jnp.float32)
    o_ref[...] = (x + scale * y).astype(o_ref.dtype)


def lora_residual_2d(x, down, up, *, scale: float, block_t: int = 256, interpret: bool = False):
    """x (T, D) -> (T, D). Grid over token blocks."""
    T, D = x.shape
    r = down.shape[1]
    bt = min(block_t, T)
    # pad T to a multiple of the block
    pad = (-T) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D, r), lambda i: (0, 0)),
            pl.BlockSpec((r, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
        interpret=interpret,
    )(x, down, up)
    return out[:T] if pad else out


# ---------------------------------------------------------------------------
# grouped (multi-tenant) variant — S-LoRA / punica idiom
# ---------------------------------------------------------------------------
#
# y[t] = x[t] + scale·(x[t]·A[idx[t]])·B[idx[t]] against a stacked adapter
# bank A (N, D, r) / B (N, r, D). Grid is (token blocks × adapters); the
# output block is revisited across the adapter axis (innermost, sequential on
# TPU) so it stays VMEM-resident: step n adds the contribution of adapter n
# to the rows that selected it, everything else contributes exact zeros
# (zeroed rows through two matmuls stay exactly zero, so mixed-tenant blocks
# match the per-tenant kernel bit-for-bit in f32). Blocks where no row uses
# adapter n skip both matmuls via pl.when — with tenant-sorted traffic each
# block pays for the adapters it actually touches, not the whole bank.

def _grouped_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref, *, scale: float):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        o_ref[...] = x_ref[...]

    sel = idx_ref[...] == n                      # (bt, 1)

    @pl.when(jnp.any(sel))
    def _accumulate():
        x = x_ref[...].astype(jnp.float32)       # (bt, D)
        xm = jnp.where(sel, x, 0.0)
        a = a_ref[0].astype(jnp.float32)         # (D, r)
        b = b_ref[0].astype(jnp.float32)         # (r, D)
        h = jnp.dot(xm, a, preferred_element_type=jnp.float32)
        y = jnp.dot(h, b, preferred_element_type=jnp.float32)
        o_ref[...] = o_ref[...] + (scale * y).astype(o_ref.dtype)


def grouped_lora_residual_2d(x, down, up, idx, *, scale: float,
                             block_t: int = 256, interpret: bool = False):
    """x (T, D), idx (T,) int32 rows into down (N, D, r) / up (N, r, D).

    idx < 0 means "no adapter" — the row passes through untouched (the
    identity slot of a serving bank). Padding rows use the same convention.
    """
    T, D = x.shape
    N, _, r = down.shape
    bt = min(block_t, T)
    pad = (-T) % bt
    idx2 = idx.astype(jnp.int32).reshape(T, 1)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        idx2 = jnp.pad(idx2, ((0, pad), (0, 0)), constant_values=-1)
    Tp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_grouped_kernel, scale=scale),
        grid=(Tp // bt, N),
        in_specs=[
            pl.BlockSpec((bt, 1), lambda i, n: (i, 0)),
            pl.BlockSpec((bt, D), lambda i, n: (i, 0)),
            pl.BlockSpec((1, D, r), lambda i, n: (n, 0, 0)),
            pl.BlockSpec((1, r, D), lambda i, n: (n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i, n: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
        interpret=interpret,
    )(idx2, x, down, up)
    return out[:T] if pad else out
