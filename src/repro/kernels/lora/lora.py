"""Fused NanoAdapter (LoRA) Pallas TPU kernel.

Computes y = x + scale·(x·A)·B without materializing the rank-r intermediate
in HBM: each grid step loads one (block_t, D) tile of tokens into VMEM, both
adapter matrices stay VMEM-resident across the whole grid (A: D×r, B: r×D —
≤ 4 MiB even at D=8192, r=64), and the two matmuls + residual add fuse into
one VMEM-round-trip. MXU alignment: block_t multiple of 8, D and r padded by
the compiler to lane multiples (r=64 is already half a lane tile; fine).

Why a kernel at all: at rank 64 the adapter matmuls are heavily
memory-bound (arithmetic intensity ≈ r ≈ 64 FLOP/B vs the MXU's ~240
FLOP/B break-even at bf16); the win is avoiding a second HBM pass over x
and the (T, r) intermediate, not FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...].astype(jnp.float32)          # (bt, D)
    a = a_ref[...].astype(jnp.float32)          # (D, r)
    b = b_ref[...].astype(jnp.float32)          # (r, D)
    h = jnp.dot(x, a, preferred_element_type=jnp.float32)
    y = jnp.dot(h, b, preferred_element_type=jnp.float32)
    o_ref[...] = (x + scale * y).astype(o_ref.dtype)


def lora_residual_2d(x, down, up, *, scale: float, block_t: int = 256, interpret: bool = False):
    """x (T, D) -> (T, D). Grid over token blocks."""
    T, D = x.shape
    r = down.shape[1]
    bt = min(block_t, T)
    # pad T to a multiple of the block
    pad = (-T) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D, r), lambda i: (0, 0)),
            pl.BlockSpec((r, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
        interpret=interpret,
    )(x, down, up)
    return out[:T] if pad else out
