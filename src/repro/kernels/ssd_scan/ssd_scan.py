"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the CUDA selective scan (DESIGN.md §3): the state-space
duality lets each Q-length chunk be computed as two MXU matmuls (intra-chunk
"attention" C·Bᵀ⊙decay and the state contraction) plus an O(1)-per-chunk
recurrence. The kernel runs grid (B, H, n_chunks) with the chunk axis
innermost/sequential; the carried state h (N × P) lives in fp32 VMEM scratch
across chunk steps (initialized at c==0), so the recurrence never touches
HBM.

Per grid step the VMEM working set is
    x (Q, P) + B, C (Q, N) + att (Q, Q) + h (N, P)
≈ 1.3 MiB at Q=256, P=64, N=128 (fp32) — comfortably VMEM-resident with
room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_scr, *, chunk: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)              # scalar (per head)
    Bm = b_ref[0, :, :].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, :, :].astype(jnp.float32)       # (Q, N)

    la = dt * A                                    # (Q,) log-decays (<= 0)
    L = jnp.cumsum(la)                             # (Q,)
    # segment decay matrix: seg[i, j] = L_i - L_j for j <= i
    li = L[:, None]
    lj = L[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(jj <= ii, li - lj, -jnp.inf)

    xdt = x * dt[:, None]                          # (Q, P)
    cb_mat = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (Q, Q)
    att = cb_mat * jnp.exp(seg)
    y_intra = jnp.dot(att, xdt, preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: y_i += exp(L_i) * C_i · h      (h: (N, P))
    y_inter = jnp.exp(L)[:, None] * jnp.dot(
        Cm, h_scr[...], preferred_element_type=jnp.float32
    )

    o_ref[0, :, 0, :] = (y_intra + y_inter).astype(o_ref.dtype)

    # state update: h' = exp(L_last) h + Σ_j exp(L_last - L_j) B_j ⊗ xdt_j
    dec_last = jnp.exp(L[-1] - L)                  # (Q,)
    h_scr[...] = jnp.exp(L[-1]) * h_scr[...] + jnp.dot(
        (Bm * dec_last[:, None]).T, xdt, preferred_element_type=jnp.float32
    )


def ssd_chunked_pallas(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """x (Bt, S, H, P); dt (Bt, S, H); A (H,); B, C (Bt, S, N) -> y like x."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=Q),
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return out[:, :S] if pad else out
