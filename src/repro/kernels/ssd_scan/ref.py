"""Pure-jnp oracle for the Mamba2 SSD chunked scan (arXiv:2405.21060 §6).

The SSD duality: the selective-SSM output
    h_t = exp(dt_t · A) h_{t-1} + dt_t · (B_t ⊗ x_t),   y_t = C_t · h_t
equals a masked attention-like form within chunks plus a low-rank inter-chunk
correction. The chunked algorithm computes, per chunk of length Q:

  intra:  y_i += Σ_{j<=i} exp(L_i - L_j) (C_i·B_j) dt_j x_j     (Q×Q matmuls)
  state:  S_c  = Σ_j exp(L_last - L_j) dt_j B_j ⊗ x_j           (chunk summary)
  inter:  y_i += exp(L_i) · C_i · H_c                            (carried state)

with L = cumsum(dt·A) inside the chunk and H_{c+1} = exp(L_last) H_c + S_c.
This matmul-dominant form is the TPU-idiomatic replacement for the CUDA
selective scan — all heavy terms map to the MXU.

This file is the slow-but-obviously-correct reference; the Pallas kernel in
``ssd_scan.py`` must match it (tests sweep shapes/dtypes vs this oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference_sequential(x, dt, A, B, C):
    """Literal O(S) recurrence — ground truth for everything else.

    x (Bt, S, H, P); dt (Bt, S, H); A (H,); B (Bt, S, N); C (Bt, S, N)
    returns y (Bt, S, H, P).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, inp):
        xt, dtt, Bt_, Ct_ = inp  # (Bt,H,P), (Bt,H), (Bt,N), (Bt,N)
        decay = jnp.exp(dtt * A)[..., None, None]          # (Bt,H,1,1)
        upd = dtt[..., None, None] * xt[..., None] * Bt_[:, None, None, :]
        h = h * decay + upd                                 # (Bt,H,P,N)
        y = jnp.sum(h * Ct_[:, None, None, :], axis=-1)     # (Bt,H,P)
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(B, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (Bt,S,H,P)


def _segsum(la):
    """la (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums.

    out[i, j] = sum_{k=j+1..i} la_k  for j <= i (the decay from step j to i).
    """
    Q = la.shape[-1]
    cs = jnp.cumsum(la, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [i, j] = L_i - L_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD — vectorized jnp oracle for the Pallas kernel.

    Same signature semantics as :func:`ssd_reference_sequential`.
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = chunk
    pad = (-S) % Q
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zf(x), zf(dt), zf(B), zf(C)
    Sp = x.shape[1]
    nc = Sp // Q

    xf = x.reshape(Bt, nc, Q, H, P).astype(jnp.float32)
    dtf = dt.reshape(Bt, nc, Q, H).astype(jnp.float32)
    Bf = B.reshape(Bt, nc, Q, N).astype(jnp.float32)
    Cf = C.reshape(Bt, nc, Q, N).astype(jnp.float32)

    la = dtf * A  # (Bt, nc, Q, H) log-decay per step (negative)
    lah = jnp.moveaxis(la, -1, 2)  # (Bt, nc, H, Q)
    L = jnp.cumsum(lah, axis=-1)   # (Bt, nc, H, Q)

    # --- intra-chunk (quadratic within chunk, MXU-friendly) ---
    seg = _segsum(lah)                                   # (Bt, nc, H, Q, Q)
    CB = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)           # (Bt, nc, Q, Q)
    att = CB[:, :, None] * jnp.exp(seg)                  # (Bt, nc, H, Q, Q)
    xdt = xf * dtf[..., None]                            # (Bt, nc, Q, H, P)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", att, xdt)

    # --- chunk states ---
    dec_last = jnp.exp(L[..., -1:] - L)                  # (Bt, nc, H, Q)
    states = jnp.einsum("bchj,bcjn,bcjhp->bchnp", dec_last, Bf, xdt)  # (Bt,nc,H,N,P)

    # --- inter-chunk recurrence (tiny scan over nc chunks) ---
    chunk_decay = jnp.exp(L[..., -1])                    # (Bt, nc, H)

    def step(h, inp):
        st, dec = inp                                    # (Bt,H,N,P), (Bt,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                  # emit state BEFORE chunk

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    _, Hs = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    Hs = jnp.moveaxis(Hs, 0, 1)                          # (Bt, nc, H, N, P)

    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", Cf, jnp.exp(jnp.moveaxis(L, 2, -1)), Hs
    )
    y = (y_intra + y_inter).reshape(Bt, Sp, H, P)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype)


def ssd_decode_step(h, x, dt, A, B, C):
    """Single decode step. h (Bt,H,P,N) carried state.

    x (Bt,H,P); dt (Bt,H); A (H,); B (Bt,N); C (Bt,N).
    Returns (y (Bt,H,P), h_new).
    """
    decay = jnp.exp(dt.astype(jnp.float32) * A)[..., None, None]
    upd = dt[..., None, None] * x[..., None] * B[:, None, None, :]
    h = h * decay + upd.astype(jnp.float32)
    y = jnp.sum(h * C[:, None, None, :], axis=-1)
    return y.astype(x.dtype), h
