"""Jitted public wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_chunked_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """Mamba2 SSD: y_t = C_t · h_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    return ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)
