"""Jitted public wrapper for the SSD chunked-scan kernel.

``chunk=None`` consults the tuning table (``repro.kernels.tuning``). Unlike
the row-tiled kernels, the chunk length changes the intra/inter-chunk split
and hence the f32 summation order, so callers that pin numerics (the model
configs pass ``chunk_size`` explicitly) keep their exact historical values.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import tuning
from repro.kernels.ssd_scan.ssd_scan import ssd_chunked_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_jit(x, dt, A, B, C, *, chunk, interpret):
    return ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def ssd(x, dt, A, B, C, *, chunk: int = None, interpret: bool = False):
    """Mamba2 SSD: y_t = C_t · h_t with h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    if chunk is None:
        chunk = tuning.ssd_chunk(x.shape[1], x.shape[-1], B.shape[-1])
    return _ssd_jit(x, dt, A, B, C, chunk=chunk, interpret=interpret)
