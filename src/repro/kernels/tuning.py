"""Block-size tuning table consumed by the kernel ``ops.py`` wrappers.

Every Pallas kernel here takes its block shape as a static argument; the
right value depends on the problem shape (VMEM working set, MXU alignment,
grid occupancy). Callers that pass an explicit block size keep it verbatim —
this module only answers when a block argument is ``None``.

Two layers:

  1. ``PINNED`` — per-(kernel, shape-bucket) winners recorded by the
     block-size sweep (``benchmarks/kernel_bench.py --sweep`` writes the raw
     sweep rows into ``BENCH_kernels.json``; the winning configs are pinned
     here by hand so a bad sweep run can't silently retune production
     kernels). Buckets are keyed on the dims that actually move the optimum.
  2. A VMEM-fit fallback for unswept shapes: the largest MXU-aligned
     candidate whose f32 working set stays under ``VMEM_BUDGET`` (half of
     the ~16 MiB v5e VMEM, leaving headroom for double buffering).

Numerics note: ``lora`` block_t and ``fisher_merge`` block_n tile fully
independent rows/columns — any block size gives bit-identical results.
``flash_attention`` block sizes reorder the online-softmax accumulation and
``ssd_scan``'s chunk changes the intra/inter-chunk split, so their tuned
values only diverge from the historical defaults (128/512 and 256) at
sequence lengths far above anything the golden-pinned tests run.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

# Half of v5e VMEM (~16 MiB/core): block working sets above this thrash.
VMEM_BUDGET = 8 * 1024 * 1024

_F32 = 4

# Sweep-pinned winners, keyed by (kernel, bucket). Buckets are coarse on
# purpose: the sweep (kernel_bench --sweep) showed the optimum moves with
# the model dim (lora), head dim (flash), and state/head dims (ssd), not
# with sequence length once the grid is large enough to fill the core.
PINNED: Dict[Tuple[str, str], Dict[str, int]] = {
    ("lora", "d<=1024"): {"block_t": 512},
    ("lora", "d<=4096"): {"block_t": 256},
    ("lora", "d>4096"): {"block_t": 128},
    ("flash_attention", "hd<=64"): {"block_q": 128, "block_k": 512},
    ("flash_attention", "hd<=128"): {"block_q": 128, "block_k": 512},
    ("flash_attention", "hd>128"): {"block_q": 128, "block_k": 256},
    ("fisher_merge", "k<=32"): {"block_n": 4096},
    ("fisher_merge", "k<=512"): {"block_n": 1024},
    ("fisher_merge", "k>512"): {"block_n": 256},
    ("ssd_scan", "np<=4096"): {"chunk": 256},
    ("ssd_scan", "np>4096"): {"chunk": 128},
}


def _bucket(value: int, edges: Tuple[int, ...], prefix: str) -> str:
    for e in edges:
        if value <= e:
            return f"{prefix}<={e}"
    return f"{prefix}>{edges[-1]}"


def _fit(candidates: Tuple[int, ...], working_set_bytes) -> int:
    """Largest candidate whose f32 working set fits the VMEM budget."""
    best = candidates[0]
    for c in candidates:
        if working_set_bytes(c) <= VMEM_BUDGET:
            best = c
    return best


def lora_block_t(t: int, d: int, r: int) -> int:
    """Token-block for the fused LoRA residual (row-independent: any value
    is numerically identical; this is purely a bandwidth/occupancy choice)."""
    cfg = PINNED.get(("lora", _bucket(d, (1024, 4096), "d")))
    if cfg:
        return min(cfg["block_t"], max(t, 8))
    # x tile + out tile + both adapters + the (bt, r) intermediate
    ws = lambda bt: (2 * bt * d + 2 * d * r + bt * r) * _F32
    return min(_fit((64, 128, 256, 512), ws), max(t, 8))


def flash_blocks(sq: int, sk: int, head_dim: int) -> Tuple[int, int]:
    """(block_q, block_k) for flash attention. Clamped by the caller to the
    actual sequence lengths, so small shapes reproduce the historical
    (128, 512) behaviour exactly."""
    cfg = PINNED.get(("flash_attention", _bucket(head_dim, (64, 128), "hd")))
    if cfg:
        return cfg["block_q"], cfg["block_k"]
    ws = lambda bk: (128 * head_dim * 2 + 2 * bk * head_dim + 128 * bk) * _F32
    return 128, _fit((128, 256, 512), ws)


def fisher_block_n(k: int, n: int) -> int:
    """Element-block for the K-client Fisher merge (column-independent:
    numerics-free). Wider blocks amortize grid overhead until the (K, bn)
    tiles blow the budget."""
    cfg = PINNED.get(("fisher_merge", _bucket(k, (32, 512), "k")))
    if cfg:
        return cfg["block_n"]
    ws = lambda bn: (2 * k * bn + bn) * _F32
    return _fit((256, 1024, 4096), ws)


def ssd_chunk(s: int, p: int, n: int) -> int:
    """Chunk length for the SSD scan: the (Q, Q) intra-chunk attention tile
    dominates the working set once Q grows past the state dims."""
    cfg = PINNED.get(("ssd_scan", _bucket(n * p, (4096,), "np")))
    if cfg:
        return cfg["chunk"]
    ws = lambda q: (2 * q * p + 2 * q * n + q * q + n * p) * _F32
    return _fit((64, 128, 256), ws)


def lookup(kernel: str, **dims) -> Dict[str, int]:
    """Generic entry point (the bench sweep uses it to label rows)."""
    if kernel == "lora":
        return {"block_t": lora_block_t(dims["t"], dims["d"], dims["r"])}
    if kernel == "flash_attention":
        bq, bk = flash_blocks(dims["sq"], dims["sk"], dims["head_dim"])
        return {"block_q": bq, "block_k": bk}
    if kernel == "fisher_merge":
        return {"block_n": fisher_block_n(dims["k"], dims["n"])}
    if kernel == "ssd_scan":
        return {"chunk": ssd_chunk(dims["s"], dims["p"], dims["n"])}
    raise KeyError(f"no tuning table for kernel {kernel!r}")
