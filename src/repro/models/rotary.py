"""Rotary position embeddings: standard RoPE and qwen2-vl M-RoPE.

M-RoPE (arXiv:2409.12191 §2.1): the head_dim/2 frequency slots are split into
``sections = (t, h, w)`` groups; each group reads a different component of a
3-component position id. Text tokens carry identical (t, h, w) components, so
M-RoPE degenerates to RoPE on text — which our stubbed-frontend dry-run uses —
but the section plumbing is real and exercised by tests with distinct (t,h,w).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) int32 -> angles (..., S, head_dim/2) f32."""
    inv = rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def mrope_angles(positions3, sections, head_dim: int, theta: float):
    """positions3 (3, B, S) -> angles (B, S, head_dim/2).

    Frequency slot i uses position component c(i) given by ``sections``:
    the first ``sections[0]`` slots read the temporal component, the next
    ``sections[1]`` the height component, the last ``sections[2]`` the width.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)  # (half,)
    sel = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=head_dim // 2
    )  # (half,) in {0,1,2}
    # gather the right component per slot: (B, S, half)
    pos = jnp.take(positions3, sel, axis=0)          # (half, B, S) -> wrong order
    pos = jnp.moveaxis(pos, 0, -1)                    # (B, S, half)
    return pos.astype(jnp.float32) * inv


def apply_rotary(x, angles):
    """x (..., S, H, D), angles (..., S, D/2) -> rotated x (same dtype).

    Uses the "rotate halves" convention (llama-style): the first D/2 dims
    pair with the last D/2. cos/sin are computed in fp32 then cast to the
    activation dtype: the rotation itself runs in bf16 (standard practice —
    orthogonal map, error ~1 ulp) so no fp32 activations leak into the
    attention dgrad collectives (EXPERIMENTS.md §Perf).
    """
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    x1 = x[..., :half]
    x2 = x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def make_angles(cfg, positions):
    """Dispatch on cfg.pos_type.

    positions: (B, S) int32 for rope; (3, B, S) for mrope. Returns
    (B, S, head_dim/2) angles, or None for learned/none position types.
    """
    hd = cfg.resolved_head_dim
    if cfg.pos_type == "rope":
        return rope_angles(positions, hd, cfg.rope_theta)
    if cfg.pos_type == "mrope":
        if positions.ndim == 2:  # text-only stream: broadcast to 3 equal components
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(positions, cfg.mrope_sections, hd, cfg.rope_theta)
    return None
