from repro.models import attention, encdec, layers, model, moe, rglru, rotary, ssm, transformer, vision_stub

__all__ = [
    "attention",
    "encdec",
    "layers",
    "model",
    "moe",
    "rglru",
    "rotary",
    "ssm",
    "transformer",
    "vision_stub",
]
