"""Core neural-net layers (pure JAX, pytree params).

Every layer is a pair of functions:

    init_<layer>(key, cfg, ...) -> params (nested dict of jnp arrays)
    <layer>(params, x, ...)     -> output

Parameters are plain dicts so the federated/aggregation/checkpoint layers can
treat everything uniformly as pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constrain, residual_spec


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype=jnp.float32, scale: float | None = None):
    """Truncated-normal fan-in init (the default for all projections)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


def init_norm(cfg, d: int, dtype=jnp.float32):
    return init_layernorm(d, dtype) if cfg.norm == "layernorm" else init_rmsnorm(d, dtype)


def norm(cfg, params, x):
    return layernorm(params, x) if cfg.norm == "layernorm" else rmsnorm(params, x)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_model: int | None = None, d_ff: int | None = None, dtype=jnp.float32):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f), dtype),
            "w_up": dense_init(k2, (d, f), dtype),
            "w_down": dense_init(k3, (f, d), dtype),
        }
    return {
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }


def mlp(cfg, params, x):
    """Position-wise MLP. Hidden activations sharded over the model axis."""
    if cfg.act in ("swiglu", "geglu"):
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        gate = constrain(gate, ("data", None, "model"))
        act = jax.nn.silu(gate) if cfg.act == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(x @ params["w_up"])
        h = constrain(h, ("data", None, "model"))
    out = h @ params["w_down"]
    return constrain(out, residual_spec(cfg))


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": embed_init(key, (vocab, d), dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, table=None):
    """Project back to vocab. ``table`` overrides for tied embeddings."""
    t = table if table is not None else params["table"]
    return x @ t.T.astype(x.dtype)


def init_learned_pos(key, max_len: int, d: int, dtype=jnp.float32):
    return {"pos": embed_init(key, (max_len, d), dtype)}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(logits, labels, mask):
    """Masked next-token cross entropy.

    logits: (B, S, V) — already shifted (logits[t] predicts labels[t]).
    labels: (B, S) int32.
    mask:   (B, S) {0,1} — 1 on supervised (answer) positions.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(hidden, table, labels, mask, *, chunk: int):
    """Blockwise fused unembed + masked CE (never materializes (B, S, V)).

    hidden (B, S, D); table (V, D); labels/mask (B, S). Scans over sequence
    chunks with a rematerialized body, so the live working set is
    (B, chunk, V) in fp32 — the memory-term optimization for the train
    shapes (see EXPERIMENTS.md §Perf).
    """
    B, S, D = hidden.shape
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    Sp = hidden.shape[1]
    nc = Sp // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        h, lab, m = inp
        lg = (h @ table.T.astype(h.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
        nll = jnp.sum((logz - gold) * m)
        return (carry[0] + nll, carry[1] + jnp.sum(m)), None

    (total, denom), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return total / jnp.maximum(denom, 1.0)


def token_accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels) * mask
    return jnp.sum(correct) / jnp.maximum(jnp.sum(mask), 1.0)
