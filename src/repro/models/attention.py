"""Attention: GQA / MHA, sliding-window, logit softcap, cross-attention,
KV-cache decode.

Layout conventions:
    x           (B, S, D)
    q           (B, S, n_heads, head_dim)
    k, v        (B, S, n_kv,   head_dim)
    cache k/v   (B, C, n_kv,   head_dim)   C = cache capacity
RoPE is applied *before* caching (keys are stored rotated), so decode never
re-rotates history. Sliding-window decode uses a ring buffer of capacity
``window`` — the mask only needs slot validity, never slot age.

Sharding: q heads over the ``model`` axis, kv heads over ``model`` when
divisible (fallback: replicated — glm4 kv=2, recurrentgemma kv=1, qwen1.5 /
whisper head counts; see DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.rotary import apply_rotary
from repro.sharding import constrain, residual_spec

NEG_INF = -2.0e38


class KVCache(NamedTuple):
    k: jax.Array  # (B, C, n_kv, head_dim)
    v: jax.Array  # (B, C, n_kv, head_dim)


def init_attention(key, cfg, cross: bool = False, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d, nh * hd), dtype),
        "wk": dense_init(kk, (d, nkv * hd), dtype),
        "wv": dense_init(kv, (d, nkv * hd), dtype),
        "wo": dense_init(ko, (nh * hd, d), dtype, scale=(nh * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def _project_q(cfg, params, x):
    B, S, _ = x.shape
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.resolved_head_dim)
    return constrain(q, ("data", None, "model", None))


def _project_kv(cfg, params, x):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bk" in params:
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    k = constrain(k, ("data", None, "model", None))
    v = constrain(v, ("data", None, "model", None))
    return k, v


def repeat_kv(cfg, kv):
    """(B, S, n_kv, hd) -> (B, S, n_heads, hd) by repeating head groups."""
    if cfg.n_kv_heads == cfg.n_heads:
        return kv
    return jnp.repeat(kv, cfg.q_per_kv, axis=2)


def _softcap(logits, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


def sdpa(cfg, q, k, v, mask, *, window: Optional[int] = None):
    """Grouped-GQA scaled-dot-product attention (pure jnp path).

    q (B,Sq,nh,hd); k,v (B,Sk,n_kv,hd) UNREPEATED — the einsums carry the
    (kv, group) factorization so repeated K/V are never materialized (the
    naive repeat costs gigabytes per layer at decode shapes).
    mask (Sq, Sk) boolean (True = attend), or None.
    """
    B, Sq, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(B, Sq, nkv, g, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    logits = _softcap(logits, cfg.logit_softcap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, nh, hd)


def chunked_sdpa(cfg, q, k, v, *, chunk: int):
    """Blockwise-softmax attention over query chunks (memory-bounded jnp path).

    Live logits shrink from (B, H, S, S) to (B, H, chunk, S) — the reason
    prefill_32k fits HBM without the Pallas kernel. Semantically identical to
    :func:`sdpa` with a causal(+window) mask. Chunks iterate under lax.scan,
    so HLO stays small; the Pallas flash kernel is the TPU production path.
    """
    B, S, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = q.shape[1]
    nc = Sp // chunk
    qs = jnp.moveaxis(q.reshape(B, nc, chunk, nkv, g, hd), 1, 0)
    kpos = jnp.arange(S)

    def f(_, inp):
        qc, ci = inp  # (B, chunk, nkv, g, hd), scalar chunk index
        logits = jnp.einsum(
            "bqkgd,bskd->bkgqs", qc.astype(jnp.float32), k.astype(jnp.float32)
        ) * (hd**-0.5)
        logits = _softcap(logits, cfg.logit_softcap)
        qpos = ci * chunk + jnp.arange(chunk)
        m = kpos[None, :] <= qpos[:, None]
        if cfg.sliding_window is not None:
            m = m & (qpos[:, None] - kpos[None, :] < cfg.sliding_window)
        logits = jnp.where(m[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return None, jnp.einsum("bkgqs,bskd->bqkgd", probs, v)

    _, outs = jax.lax.scan(f, None, (qs, jnp.arange(nc)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sp, nh, hd)
    return out[:, :S] if pad else out


def causal_mask(sq: int, sk: int, *, q_offset: int = 0, window: Optional[int] = None):
    """(Sq, Sk) boolean mask. Query i has absolute position q_offset + i."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    m = kpos[None, :] <= qpos[:, None]
    if window is not None:
        m = m & (qpos[:, None] - kpos[None, :] < window)
    return m


def full_attention(cfg, params, x, angles, *, causal: bool = True,
                   memory=None, return_kv: bool = False):
    """Full-sequence attention for train/prefill.

    memory: (B, M, D) for cross-attention (no mask, keys from memory).
    Returns (out, (k, v)) when return_kv (pre-repeat KV for cache seeding).
    """
    q = _project_q(cfg, params, x)
    kv_src = memory if memory is not None else x
    k, v = _project_kv(cfg, params, kv_src)
    if angles is not None and memory is None:
        q = apply_rotary(q, angles)
        k = apply_rotary(k, angles)
    # Context-parallel queries for head counts that don't divide the model
    # axis (qwen1.5: 20 heads vs 16): instead of replicating the whole
    # attention block (16x wasted FLOPs), shard the QUERY sequence over
    # `model` and replicate K/V — compute balances, k/v are all-gathered
    # once per layer (EXPERIMENTS.md §Perf, qwen1.5/prefill).
    from repro.sharding import current_mesh

    mesh = current_mesh()
    if (
        mesh is not None
        and causal
        and memory is None
        and getattr(cfg, "ctx_parallel_attn", False)
        and cfg.n_heads % mesh.shape.get("model", 1) != 0
    ):
        q = constrain(q, ("data", "model", None, None))
        k = constrain(k, ("data", None, None, None))
        v = constrain(v, ("data", None, None, None))
    mask = None
    if causal and memory is None:
        mask = causal_mask(x.shape[1], x.shape[1], window=cfg.sliding_window)
    if cfg.use_pallas and memory is None and causal:
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            softcap=cfg.logit_softcap, interpret=True,
        )
    elif (
        causal
        and memory is None
        and cfg.attn_chunk is not None
        and x.shape[1] > cfg.attn_chunk
    ):
        out = chunked_sdpa(cfg, q, k, v, chunk=cfg.attn_chunk)
    else:
        out = sdpa(cfg, q, k, v, mask)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.resolved_head_dim)
    out = out @ params["wo"]
    out = constrain(out, residual_spec(cfg))
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def cache_capacity(cfg, seq_len: int) -> int:
    """SWA archs bound the live KV by the window (ring buffer)."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg, batch: int, capacity: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, capacity, cfg.n_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def seed_cache(cfg, cache: KVCache, k, v, *, start: int = 0) -> KVCache:
    """Write prefill KV (already rotated) into the cache at [start, start+S)."""
    C = cache.k.shape[1]
    S = k.shape[1]
    if S > C:
        # Sliding-window ring: only the last C positions survive, and position
        # p must land at slot p % C so later decode writes (slot = pos % C)
        # overwrite the oldest entry. roll by S % C achieves exactly that.
        k = jnp.roll(k[:, -C:], S % C, axis=1)
        v = jnp.roll(v[:, -C:], S % C, axis=1)
        start = 0
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, start, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, start, 0, 0))
    return KVCache(ck, cv)


def decode_attention(cfg, params, x, angles, cache: KVCache, pos):
    """One-token decode: x (B, 1, D), pos scalar int32 (absolute position).

    Writes the new KV at slot ``pos % C`` (ring semantics — for full caches
    C == seq_len so the slot is just ``pos``) and attends over valid slots.
    Returns (out (B,1,D), new_cache).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    C = cache.k.shape[1]
    q = _project_q(cfg, params, x)
    k, v = _project_kv(cfg, params, x)
    if angles is not None:
        q = apply_rotary(q, angles)
        k = apply_rotary(k, angles)
    slot = jnp.mod(pos, C)
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    new_cache = KVCache(ck, cv)
    # slot j valid iff it has been written: j <= pos (ring: pos >= C -> all valid)
    valid = jnp.arange(C) <= pos  # (C,) — covers both ring and linear cases
    nkv = cfg.n_kv_heads
    g = cfg.n_heads // nkv
    qg = q.reshape(B, 1, nkv, g, hd)
    # Align q's sharding with the KV-cache layout (EXPERIMENTS.md §Perf,
    # grok/decode): when kv-heads don't divide the model axis the cache is
    # head_dim-sharded; constraining q the same way replaces the per-layer
    # "involuntary full rematerialization" cache copies with one small
    # fp32 logits all-reduce (contraction over the sharded head_dim).
    from repro.sharding import current_mesh

    mesh = current_mesh()
    if mesh is not None:
        msize = mesh.shape.get("model", 1)
        if nkv % msize == 0:
            qg = constrain(qg, ("data", None, "model", None, None))
        elif hd % msize == 0:
            qg = constrain(qg, ("data", None, None, None, "model"))
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, ck, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    logits = _softcap(logits, cfg.logit_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ params["wo"]
    return constrain(out, ("data", None, None)), new_cache


def cross_decode_attention(cfg, params, x, mem_kv: KVCache):
    """Decoder cross-attention against a fixed (precomputed) encoder memory."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = _project_q(cfg, params, x)
    nkv = cfg.n_kv_heads
    g = cfg.n_heads // nkv
    qg = q.reshape(B, 1, nkv, g, hd)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, mem_kv.k, preferred_element_type=jnp.float32
    ) * (hd**-0.5)
    probs = jax.nn.softmax(logits, axis=-1).astype(mem_kv.v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, mem_kv.v)
    out = out.reshape(B, 1, cfg.n_heads * hd) @ params["wo"]
    return constrain(out, ("data", None, None))
