"""Mixture-of-Experts layer — GShard/GSPMD-style grouped einsum dispatch.

TPU adaptation (DESIGN.md §3): instead of a CUDA gather/scatter (megablocks)
dispatch, tokens are partitioned into fixed-size *groups*; dispatch/combine
are dense one-hot einsums of size tokens × E × capacity. Under pjit with
experts sharded on the ``model`` axis and groups on ``data``, XLA emits the
canonical all-to-all pair around the expert FFN — exactly the collective the
roofline analysis tracks for the MoE architectures.

Capacity per expert per group: C = round_up(G * top_k * cf / E, 4). Priority
is choice-major (all top-1 picks rank before any top-2 pick), so a token's
primary expert is never dropped because of someone's secondary choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_mlp, mlp
from repro.sharding import constrain


def _group_size(tokens: int, target: int = 512) -> int:
    if tokens <= target:
        return tokens
    if tokens % target == 0:
        return target
    g = target
    while g > 1 and tokens % g != 0:
        g -= 1
    return g


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def init_moe(key, cfg, dtype=jnp.float32):
    mcfg = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, mcfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, (d, E), dtype=jnp.float32),
        "w_gate": dense_init(kg, (E, d, f), dtype),
        "w_up": dense_init(ku, (E, d, f), dtype),
        "w_down": dense_init(kd, (E, f, d), dtype, scale=f**-0.5),
    }
    if mcfg.shared_d_ff:
        p["shared"] = init_mlp(ks, cfg, d_ff=mcfg.shared_d_ff, dtype=dtype)
    return p


def moe_apply(cfg, params, x):
    """x (B, S, D) -> (y (B, S, D), aux) with aux = {"lb_loss": scalar}."""
    mcfg = cfg.moe
    B, S, D = x.shape
    E, K = mcfg.n_experts, mcfg.top_k
    tokens = B * S
    G = _group_size(tokens)
    n_g = tokens // G
    C = max(1, _round_up(int(G * K * mcfg.capacity_factor / E + 0.999), 4))
    C = min(C, G * K)

    xg = x.reshape(n_g, G, D)
    xg = constrain(xg, ("data", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)            # (g, G, E)
    gates, idx = jax.lax.top_k(probs, K)               # (g, G, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)     # (g, G, K, E)

    # choice-major priority ranking within each expert
    # rank contribution of earlier choices (all tokens) + earlier tokens (same choice)
    counts_per_choice = jnp.sum(oh, axis=1)            # (g, K, E)
    prev_choice = jnp.cumsum(counts_per_choice, axis=1) - counts_per_choice  # (g, K, E)
    within = jnp.cumsum(oh, axis=1) - oh               # (g, G, K, E)
    rank = within + prev_choice[:, None]               # (g, G, K, E)
    rank_sel = jnp.sum(rank * oh, axis=-1)             # (g, G, K)
    keep = (rank_sel < C).astype(jnp.float32)

    pos_oh = jax.nn.one_hot(
        jnp.minimum(rank_sel, C - 1).astype(jnp.int32), C, dtype=jnp.float32
    )
    disp_k = oh[..., None] * pos_oh[..., None, :] * keep[..., None, None]  # (g,G,K,E,C)
    dispatch = jnp.sum(disp_k, axis=2)                 # (g, G, E, C)
    combine = jnp.sum(disp_k * gates[..., None, None], axis=2)             # (g, G, E, C)

    xe = jnp.einsum("gtec,gtd->egcd", dispatch.astype(x.dtype), xg)        # (E, g, C, D)
    xe = constrain(xe, ("model", "data", None, None))

    h_gate = jnp.einsum("egcd,edf->egcf", xe, params["w_gate"])
    h_up = jnp.einsum("egcd,edf->egcf", xe, params["w_up"])
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu(h_gate) if cfg.act == "swiglu" else jax.nn.gelu(h_gate)
        h = act * h_up
    else:
        h = jax.nn.gelu(h_up)
    eo = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    eo = constrain(eo, ("model", "data", None, None))

    y = jnp.einsum("gtec,egcd->gtd", combine.astype(x.dtype), eo)          # (g, G, D)
    y = constrain(y, ("data", None, None))
    y = y.reshape(B, S, D)

    if "shared" in params:
        y = y + mlp(cfg, params["shared"], x)

    # GShard load-balance auxiliary (reported; backbone is frozen under FedNano)
    frac_tokens = jnp.mean(oh[:, :, 0, :], axis=1)     # (g, E) top-1 assignment share
    mean_prob = jnp.mean(probs, axis=1)                # (g, E)
    lb = E * jnp.mean(jnp.sum(frac_tokens * mean_prob, axis=-1))
    return y, {"lb_loss": lb}
