"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c · softplus(Λ) · r_t)       c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

TPU adaptation: train/prefill runs the linear recurrence with
``jax.lax.associative_scan`` (log-depth, VPU-friendly) instead of a CUDA
sequential kernel; decode is the O(1) step.

Block layout (the "recurrent block" of Griffin):
    u -> [branch A: linear -> GeLU] ⊙ [branch B: linear -> conv1d -> RG-LRU] -> linear
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import constrain

_C = 8.0


class RGLRUState(NamedTuple):
    conv: jax.Array  # (B, conv_width-1, d_rnn)
    h: jax.Array     # (B, d_rnn) f32


def _d_rnn(cfg):
    return cfg.rglru.d_rnn or cfg.d_model


def init_rglru(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    dr = _d_rnn(cfg)
    cw = cfg.rglru.conv_width
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (paper App. A)
    lam = jax.random.uniform(k6, (dr,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(lam) / (2 * _C)))  # inverse of a = exp(-c softplus(Λ))
    return {
        "w_gate_branch": dense_init(k1, (d, dr), dtype),
        "w_rec_branch": dense_init(k2, (d, dr), dtype),
        "conv_w": (jax.random.normal(k3, (cw, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": dense_init(k4, (dr, dr), dtype),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": dense_init(k5, (dr, dr), dtype),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 7), (dr, d), dtype, scale=dr**-0.5),
        "lam": lam.astype(jnp.float32),
    }


def _causal_conv(params, x):
    w = params["conv_w"].astype(x.dtype)
    cw = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + pads[:, i : i + x.shape[1]] * w[i]
    return out + params["conv_b"].astype(x.dtype)


def _gates(params, x):
    """x (..., dr) -> (log_a, beta·gated-input multiplier) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r         # (..., dr), ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    return a, beta * i * xf


def rglru_scan(params, x, length=None):
    """Full-sequence RG-LRU via associative scan. x (B, S, dr) -> (B, S, dr).

    ``length`` (scalar int32, optional) forces the gates to the scan's
    identity element ``(a=1, b=0)`` past the valid prefix, so pad steps carry
    the hidden state through unchanged — the serving engine's right-padded
    prefill hinges on this.
    """
    a, b = _gates(params, x)  # both (B, S, dr) f32
    if length is not None:
        valid = (jnp.arange(x.shape[1]) < jnp.asarray(length, jnp.int32))
        valid = valid[None, :, None]
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(x.dtype), (aa, hh)


def rglru_block(cfg, params, u):
    """Full recurrent block. u (B, S, D) -> (B, S, D)."""
    gate = jax.nn.gelu((u @ params["w_gate_branch"]).astype(jnp.float32)).astype(u.dtype)
    rec_in = _causal_conv(params, u @ params["w_rec_branch"])
    rec_in = constrain(rec_in, ("data", None, "model"))
    h, _ = rglru_scan(params, rec_in)
    y = (h * gate) @ params["w_out"]
    return constrain(y, ("data", None, None))


def init_rglru_state(cfg, batch: int, dtype) -> RGLRUState:
    dr = _d_rnn(cfg)
    cw = cfg.rglru.conv_width
    return RGLRUState(
        conv=jnp.zeros((batch, cw - 1, dr), dtype),
        h=jnp.zeros((batch, dr), jnp.float32),
    )


def rglru_block_prefill(cfg, params, u, length=None):
    """Full block + terminal RGLRUState for decode.

    With ``length`` set, gate masking in :func:`rglru_scan` makes pad steps
    identity, so ``hh[:, -1]`` IS the state after the last valid token; the
    conv window is sliced at the valid length (zero-extended on the left,
    matching the causal-conv boundary).
    """
    gate = jax.nn.gelu((u @ params["w_gate_branch"]).astype(jnp.float32)).astype(u.dtype)
    pre_conv = u @ params["w_rec_branch"]
    rec_in = _causal_conv(params, pre_conv)
    h, (_, hh) = rglru_scan(params, rec_in, length=length)
    y = (h * gate) @ params["w_out"]
    cw = cfg.rglru.conv_width
    # zero-left-extend so prompts shorter than cw-1 still give a full window
    zext = jnp.concatenate(
        [jnp.zeros((u.shape[0], cw - 1, pre_conv.shape[-1]),
                   pre_conv.dtype), pre_conv], axis=1)
    if length is None:
        conv_tail = zext[:, -(cw - 1) :, :]
    else:
        conv_tail = jax.lax.dynamic_slice_in_dim(
            zext, jnp.asarray(length, jnp.int32), cw - 1, axis=1)
    state = RGLRUState(conv=conv_tail, h=hh[:, -1].astype(jnp.float32))
    return y, state


def rglru_block_step(cfg, params, u, state: RGLRUState):
    """One-token decode. u (B, 1, D) -> (out (B, 1, D), new state)."""
    x = u[:, 0]
    gate = jax.nn.gelu((x @ params["w_gate_branch"]).astype(jnp.float32)).astype(x.dtype)
    pre = x @ params["w_rec_branch"]  # (B, dr)
    window = jnp.concatenate([state.conv, pre[:, None, :]], axis=1)
    w = params["conv_w"].astype(pre.dtype)
    rec_in = jnp.sum(window * w[None], axis=1) + params["conv_b"].astype(pre.dtype)
    a, b = _gates(params, rec_in)  # (B, dr)
    h_new = a * state.h + b
    y = (h_new.astype(x.dtype) * gate) @ params["w_out"]
    return y[:, None, :], RGLRUState(conv=window[:, 1:], h=h_new)
