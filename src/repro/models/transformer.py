"""Decoder-only transformer stacks: dense / moe / ssm / hybrid.

Layers are **scanned** (``lax.scan`` over stacked per-layer params) so HLO
size is O(1) in depth — 80-layer dry-runs stay tractable — with optional
``jax.checkpoint`` (remat) on the scanned body for training.

Layer bodies by family:
    dense/vlm : x += attn(norm(x));  x += mlp(norm(x))
    moe       : x += attn(norm(x));  x += moe(norm(x))   (+ shared expert)
    ssm       : x += mamba2(norm(x))
    hybrid    : 12 × (rec, rec, attn) triples + 2 trailing rec layers,
                every sub-layer followed by its own MLP (Griffin residual
                pattern); attn sub-layers use the local window.

All three execution modes share layer params:
    forward_stack   — full sequence, no state (training loss path)
    prefill_stack   — full sequence, returns stacked decode state
    decode_stack    — one token, consumes/produces stacked decode state
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import init_mlp, init_norm, mlp, norm
from repro.models.moe import init_moe, moe_apply
from repro.sharding import constrain, residual_spec


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _attn_cfg(cfg):
    """Attention-sublayer view of the config (hybrid uses the local window)."""
    if cfg.family == "hybrid":
        return cfg.with_(sliding_window=cfg.rglru.local_window)
    return cfg


def init_dense_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype=dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg, dtype=dtype),
    }


def init_moe_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype=dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "moe": init_moe(k2, cfg, dtype=dtype),
    }


def init_ssm_layer(key, cfg, dtype):
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "ssm": ssm_lib.init_ssm(key, cfg, dtype=dtype),
    }


def init_rec_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "rgl": rglru_lib.init_rglru(k1, cfg, dtype=dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg, dtype=dtype),
    }


def init_attn_mix_layer(key, cfg, dtype):
    """Hybrid attention sub-layer (same structure as dense)."""
    return init_dense_layer(key, _attn_cfg(cfg), dtype)


def hybrid_split(cfg) -> Tuple[int, int]:
    """(n_triples, n_extra_rec) — 38 = 12×3 + 2 for recurrentgemma-9b."""
    n_triples = cfg.n_layers // 3
    n_extra = cfg.n_layers - 3 * n_triples
    return n_triples, n_extra


def init_stack(key, cfg, dtype):
    """Stacked per-layer params for the decoder stack."""
    if cfg.family == "hybrid":
        n_t, n_e = hybrid_split(cfg)
        kt, ke = jax.random.split(key)

        def init_triple(k):
            k0, k1, k2 = jax.random.split(k, 3)
            return {
                "rec0": init_rec_layer(k0, cfg, dtype),
                "rec1": init_rec_layer(k1, cfg, dtype),
                "attn": init_attn_mix_layer(k2, cfg, dtype),
            }

        triples = jax.vmap(init_triple)(jax.random.split(kt, n_t))
        extras = (
            jax.vmap(lambda k: init_rec_layer(k, cfg, dtype))(jax.random.split(ke, n_e))
            if n_e
            else None
        )
        return {"triples": triples, "extras": extras}

    init_one = {
        "dense": init_dense_layer,
        "vlm": init_dense_layer,
        "audio": init_dense_layer,  # used for the whisper *encoder* stack
        "moe": init_moe_layer,
        "ssm": init_ssm_layer,
    }[cfg.family]
    layers = jax.vmap(lambda k: init_one(k, cfg, dtype))(
        jax.random.split(key, cfg.n_layers)
    )
    return {"layers": layers}


# ---------------------------------------------------------------------------
# layer bodies (single layer, full sequence)
# ---------------------------------------------------------------------------

def dense_body(cfg, lp, x, angles):
    # seq_parallel: residual lives sequence-sharded; the block input is
    # all-gathered exactly at the norm output (Megatron-SP AG point) so the
    # attention/MLP interior keeps its tensor-parallel layout. With
    # seq_parallel off NO constraint is inserted at all — even identity
    # constraints perturb XLA fusion (EXPERIMENTS.md §Perf, glm4 iter 3).
    sp = getattr(cfg, "seq_parallel", False)
    if sp:
        x = constrain(x, residual_spec(cfg))
    h = norm(cfg, lp["norm1"], x)
    if sp:
        h = constrain(h, ("data", None, None))
    x = x + attn_lib.full_attention(cfg, lp["attn"], h, angles)
    if sp:
        x = constrain(x, residual_spec(cfg))
    h = norm(cfg, lp["norm2"], x)
    if sp:
        h = constrain(h, ("data", None, None))
    x = x + mlp(cfg, lp["mlp"], h)
    return x, jnp.float32(0.0)


def moe_body(cfg, lp, x, angles):
    sp = getattr(cfg, "seq_parallel", False)
    if sp:
        x = constrain(x, residual_spec(cfg))
    h = norm(cfg, lp["norm1"], x)
    if sp:
        h = constrain(h, ("data", None, None))
    x = x + attn_lib.full_attention(cfg, lp["attn"], h, angles)
    if sp:
        x = constrain(x, residual_spec(cfg))
    h = norm(cfg, lp["norm2"], x)
    if sp:
        h = constrain(h, ("data", None, None))
    y, aux = moe_apply(cfg, lp["moe"], h)
    return x + y, aux["lb_loss"]


def ssm_body(cfg, lp, x, angles):
    x = x + ssm_lib.ssm_apply(cfg, lp["ssm"], norm(cfg, lp["norm1"], x),
                              use_pallas=cfg.use_pallas)
    return x, jnp.float32(0.0)


def rec_body(cfg, lp, x, angles):
    x = x + rglru_lib.rglru_block(cfg, lp["rgl"], norm(cfg, lp["norm1"], x))
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    return x, jnp.float32(0.0)


def hybrid_triple_body(cfg, lp, x, angles):
    x, _ = rec_body(cfg, lp["rec0"], x, angles)
    x, _ = rec_body(cfg, lp["rec1"], x, angles)
    x, _ = dense_body(_attn_cfg(cfg), lp["attn"], x, angles)
    return x, jnp.float32(0.0)


_BODY = {
    "dense": dense_body,
    "vlm": dense_body,
    "audio": dense_body,
    "moe": moe_body,
    "ssm": ssm_body,
}


# ---------------------------------------------------------------------------
# full-sequence forward (training loss path)
# ---------------------------------------------------------------------------

def _unstack(stacked, i):
    return jax.tree.map(lambda a: a[i], stacked)


def _n_stacked(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def _scan_layers(body, x, stacked, remat: bool, scan: bool = True):
    """Run ``body`` over stacked layer params.

    scan=True: lax.scan (HLO size O(1) in depth — production path).
    scan=False: unrolled python loop (dry-run roofline path: XLA's
    cost_analysis counts while-loop bodies ONCE, so the roofline lowering
    unrolls to get true per-step FLOPs/bytes/collectives).
    """

    def f(carry, lp):
        y, aux = body(carry, lp)
        return y, aux

    if remat:
        f = jax.checkpoint(f, prevent_cse=False)
    if scan:
        x, auxs = jax.lax.scan(f, x, stacked)
        return x, jnp.sum(auxs)
    aux_total = jnp.float32(0.0)
    for i in range(_n_stacked(stacked)):
        x, aux = f(x, _unstack(stacked, i))
        aux_total = aux_total + aux
    return x, aux_total


def forward_stack(cfg, stack, x, angles):
    """x (B, S, D) -> (hidden (B, S, D), aux_loss scalar)."""
    if cfg.family == "hybrid":
        body = functools.partial(hybrid_triple_body, cfg)
        x, aux = _scan_layers(lambda c, lp: body(lp, c, angles), x,
                              stack["triples"], cfg.remat, cfg.scan_layers)
        if stack["extras"] is not None:
            body_e = functools.partial(rec_body, cfg)
            x, aux2 = _scan_layers(lambda c, lp: body_e(lp, c, angles), x,
                                   stack["extras"], cfg.remat, cfg.scan_layers)
            aux = aux + aux2
        return x, aux
    body = functools.partial(_BODY[cfg.family], cfg)
    return _scan_layers(lambda c, lp: body(lp, c, angles), x, stack["layers"],
                        cfg.remat, cfg.scan_layers)


def _scan_emit(f, x, xs, scan: bool):
    """lax.scan or unrolled loop for carry+emit bodies (prefill/decode)."""
    if scan:
        return jax.lax.scan(f, x, xs)
    n = _n_stacked(xs)
    ys = []
    for i in range(n):
        x, y = f(x, _unstack(xs, i))
        ys.append(y)
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return x, stacked


# ---------------------------------------------------------------------------
# prefill: full sequence + decode state
# ---------------------------------------------------------------------------

def _attn_prefill(cfg, lp, x, angles, capacity: int):
    h = norm(cfg, lp["norm1"], x)
    out, (k, v) = attn_lib.full_attention(cfg, lp["attn"], h, angles, return_kv=True)
    x = x + out
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    cache = attn_lib.init_cache(cfg, x.shape[0], capacity, x.dtype)
    cache = attn_lib.seed_cache(cfg, cache, k, v, start=0)
    return x, cache


def _rec_prefill(cfg, lp, x, angles, length=None):
    h = norm(cfg, lp["norm1"], x)
    out, state = rglru_lib.rglru_block_prefill(cfg, lp["rgl"], h, length=length)
    x = x + out
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    return x, state


def _ssm_prefill(cfg, lp, x, length=None):
    h = norm(cfg, lp["norm1"], x)
    out, state = ssm_lib.ssm_prefill(cfg, lp["ssm"], h, length=length)
    return x + out, state


def _moe_prefill(cfg, lp, x, angles, capacity: int):
    h = norm(cfg, lp["norm1"], x)
    out, (k, v) = attn_lib.full_attention(cfg, lp["attn"], h, angles, return_kv=True)
    x = x + out
    y, _ = moe_apply(cfg, lp["moe"], norm(cfg, lp["norm2"], x))
    x = x + y
    cache = attn_lib.init_cache(cfg, x.shape[0], capacity, x.dtype)
    cache = attn_lib.seed_cache(cfg, cache, k, v, start=0)
    return x, cache


def prefill_stack(cfg, stack, x, angles, capacity: int, length=None):
    """Returns (hidden, stacked decode state).

    ``length`` (scalar int32, optional) marks only the first ``length``
    positions as real — recurrent sub-layers (ssm / rg-lru) gate their state
    updates so right-padding never leaks into the terminal decode state.
    Attention caches need no masking: pad KV is position-invalidated and
    overwritten before it becomes reachable (see serving.engine docstring).
    """
    if cfg.family == "hybrid":
        acfg = _attn_cfg(cfg)
        acap = attn_lib.cache_capacity(acfg, capacity)

        def f(c, lp):
            c, s0 = _rec_prefill(cfg, lp["rec0"], c, angles, length)
            c, s1 = _rec_prefill(cfg, lp["rec1"], c, angles, length)
            c, kv = _attn_prefill(acfg, lp["attn"], c, angles, acap)
            return c, {"rec0": s0, "rec1": s1, "attn": kv}

        x, st_t = _scan_emit(f, x, stack["triples"], cfg.scan_layers)
        state = {"triples": st_t, "extras": None}
        if stack["extras"] is not None:
            def fe(c, lp):
                return _rec_prefill(cfg, lp, c, angles, length)
            x, st_e = _scan_emit(fe, x, stack["extras"], cfg.scan_layers)
            state["extras"] = st_e
        return x, state

    if cfg.family == "ssm":
        def f(c, lp):
            return _ssm_prefill(cfg, lp, c, length)
        x, states = _scan_emit(f, x, stack["layers"], cfg.scan_layers)
        return x, {"layers": states}

    cap = attn_lib.cache_capacity(cfg, capacity)
    pre = _moe_prefill if cfg.family == "moe" else _attn_prefill

    def f(c, lp):
        return pre(cfg, lp, c, angles, cap)

    x, caches = _scan_emit(f, x, stack["layers"], cfg.scan_layers)
    return x, {"layers": caches}


# ---------------------------------------------------------------------------
# decode: one token
# ---------------------------------------------------------------------------

def _attn_step(cfg, lp, x, angles, cache, pos):
    h = norm(cfg, lp["norm1"], x)
    out, cache = attn_lib.decode_attention(cfg, lp["attn"], h, angles, cache, pos)
    x = x + out
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    return x, cache


def _moe_step(cfg, lp, x, angles, cache, pos):
    h = norm(cfg, lp["norm1"], x)
    out, cache = attn_lib.decode_attention(cfg, lp["attn"], h, angles, cache, pos)
    x = x + out
    y, _ = moe_apply(cfg, lp["moe"], norm(cfg, lp["norm2"], x))
    return x + y, cache


def _rec_step(cfg, lp, x, state):
    h = norm(cfg, lp["norm1"], x)
    out, state = rglru_lib.rglru_block_step(cfg, lp["rgl"], h, state)
    x = x + out
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    return x, state


def _ssm_step(cfg, lp, x, state):
    h = norm(cfg, lp["norm1"], x)
    out, state = ssm_lib.ssm_decode_step(cfg, lp["ssm"], h, state)
    return x + out, state


def decode_stack(cfg, stack, x, angles, state, pos):
    """x (B, 1, D), stacked state -> (hidden (B, 1, D), new state)."""
    if cfg.family == "hybrid":
        acfg = _attn_cfg(cfg)

        def f(c, inp):
            lp, st = inp
            c, s0 = _rec_step(cfg, lp["rec0"], c, st["rec0"])
            c, s1 = _rec_step(cfg, lp["rec1"], c, st["rec1"])
            c, kv = _attn_step(acfg, lp["attn"], c, angles, st["attn"], pos)
            return c, {"rec0": s0, "rec1": s1, "attn": kv}

        x, st_t = _scan_emit(f, x, (stack["triples"], state["triples"]), cfg.scan_layers)
        new_state = {"triples": st_t, "extras": None}
        if stack["extras"] is not None:
            def fe(c, inp):
                lp, st = inp
                return _rec_step(cfg, lp, c, st)
            x, st_e = _scan_emit(fe, x, (stack["extras"], state["extras"]), cfg.scan_layers)
            new_state["extras"] = st_e
        return x, new_state

    if cfg.family == "ssm":
        def f(c, inp):
            lp, st = inp
            return _ssm_step(cfg, lp, c, st)
        x, states = _scan_emit(f, x, (stack["layers"], state["layers"]), cfg.scan_layers)
        return x, {"layers": states}

    step = _moe_step if cfg.family == "moe" else _attn_step

    def f(c, inp):
        lp, st = inp
        return step(cfg, lp, c, angles, st, pos)

    x, caches = _scan_emit(f, x, (stack["layers"], state["layers"]), cfg.scan_layers)
    return x, {"layers": caches}


def init_decode_state(cfg, batch: int, capacity: int, dtype):
    """Zero decode state with the right stacked structure (for dry-run specs)."""
    if cfg.family == "hybrid":
        n_t, n_e = hybrid_split(cfg)
        acfg = _attn_cfg(cfg)
        acap = attn_lib.cache_capacity(acfg, capacity)

        def one_triple(_):
            return {
                "rec0": rglru_lib.init_rglru_state(cfg, batch, dtype),
                "rec1": rglru_lib.init_rglru_state(cfg, batch, dtype),
                "attn": attn_lib.init_cache(acfg, batch, acap, dtype),
            }

        triples = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one_triple(i) for i in range(n_t)]
        )
        extras = None
        if n_e:
            extras = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[rglru_lib.init_rglru_state(cfg, batch, dtype) for _ in range(n_e)],
            )
        return {"triples": triples, "extras": extras}

    if cfg.family == "ssm":
        states = [ssm_lib.init_ssm_state(cfg, batch, dtype) for _ in range(cfg.n_layers)]
        return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *states)}

    cap = attn_lib.cache_capacity(cfg, capacity)
    caches = [attn_lib.init_cache(cfg, batch, cap, dtype) for _ in range(cfg.n_layers)]
    return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *caches)}
