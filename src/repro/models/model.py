"""Backbone facade — one uniform API over all six architecture families.

The *backbone* is the server-side frozen model of FedNano: token embedding,
connector, layer stack, final norm, unembedding. NanoEdge (client-side
encoders + adapters) lives in ``repro.core`` and feeds this module
**embeddings**, never raw tokens — mirroring the split-learning interface.

API (module-level functions, ``cfg`` first):
    init_backbone(key, cfg)                      -> params
    embed_tokens(cfg, params, tokens)            -> (B, S, D)
    connect(cfg, params, feats)                  -> (B, M, D)   connector
    forward(cfg, params, embeds, positions, enc_embeds=None) -> (hidden, aux)
    logits(cfg, params, hidden)                  -> (B, S, V)
    prefill(cfg, params, embeds, positions, capacity, enc_embeds=None)
    decode_step(cfg, params, embed, state, pos)  -> (logits, state)
    init_state(cfg, batch, capacity, dtype)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.layers import (
    dense_init,
    init_embedding,
    init_learned_pos,
    init_norm,
    norm,
    unembed,
)
from repro.models.rotary import make_angles
from repro.sharding import constrain


def param_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_backbone(key, cfg):
    dtype = param_dtype(cfg)
    keys = jax.random.split(key, 8)
    params = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(keys[1], cfg.vocab_size, cfg.d_model, dtype)
    if cfg.pos_type == "learned":
        params["pos"] = init_learned_pos(keys[2], cfg.max_seq_len, cfg.d_model, dtype)
    if cfg.frontend_dim:
        params["connector"] = {
            "w": dense_init(keys[3], (cfg.frontend_dim, cfg.d_model), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    if cfg.family == "audio":
        params.update(encdec.init_encdec_stacks(keys[4], cfg, dtype))
        params["enc_pos"] = init_learned_pos(keys[5], cfg.enc_seq_len, cfg.d_model, dtype)
        params["enc_final_norm"] = init_norm(cfg, cfg.d_model, dtype)
    else:
        params.update(transformer.init_stack(keys[4], cfg, dtype))
    return params


def embed_tokens(cfg, params, tokens):
    emb = jnp.take(params["embed"]["table"], tokens, axis=0)
    return constrain(emb, ("data", None, None))


def connect(cfg, params, feats):
    """Frozen modality connector: (B, M, frontend_dim) -> (B, M, D)."""
    c = params["connector"]
    return feats.astype(c["w"].dtype) @ c["w"] + c["b"]


def _add_learned_pos(cfg, params, x, positions):
    if cfg.pos_type != "learned":
        return x
    pos_emb = jnp.take(params["pos"]["pos"], positions, axis=0)  # (B, S, D)
    return x + pos_emb.astype(x.dtype)


def _encode_memory(cfg, params, enc_embeds):
    """Whisper encoder over connected frame embeddings (B, M, D)."""
    m = enc_embeds.shape[1]
    pos = jnp.arange(m)
    mem = enc_embeds + params["enc_pos"]["pos"][pos][None].astype(enc_embeds.dtype)
    mem = encdec.encode(cfg, params, mem)
    return norm(cfg, params["enc_final_norm"], mem)


def forward(cfg, params, embeds, positions, enc_embeds: Optional[jax.Array] = None):
    """Full-sequence causal forward.

    embeds (B, S, D) — adapter-processed input embeddings.
    positions (B, S) int32 (or (3, B, S) for mrope).
    enc_embeds (B, M, D) — connected frame embeddings (audio family only).
    Returns (hidden (B, S, D), aux scalar).
    """
    x = _add_learned_pos(cfg, params, embeds, positions if positions.ndim == 2 else positions[0])
    angles = make_angles(cfg, positions)
    if cfg.family == "audio":
        memory = _encode_memory(cfg, params, enc_embeds)
        x, aux = encdec.decode_forward(cfg, params, x, memory)
    else:
        x, aux = transformer.forward_stack(cfg, params, x, angles)
    return norm(cfg, params["final_norm"], x), aux


def logits(cfg, params, hidden):
    table = params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]
    out = unembed({"table": table}, hidden)
    return constrain(out, ("data", None, "model"))


def loss_fn(cfg, params, embeds, positions, labels, mask, enc_embeds=None):
    from repro.models.layers import chunked_lm_loss, lm_loss

    hidden, aux = forward(cfg, params, embeds, positions, enc_embeds)
    if cfg.loss_chunk is not None and hidden.shape[1] > cfg.loss_chunk:
        table = params["embed"]["table"] if cfg.tie_embeddings else params["unembed"]["table"]
        return chunked_lm_loss(hidden, table, labels, mask, chunk=cfg.loss_chunk), aux
    lg = logits(cfg, params, hidden)
    return lm_loss(lg, labels, mask), aux


def prefill(cfg, params, embeds, positions, capacity: int, enc_embeds=None,
            length=None):
    """Returns (state, hidden) — state is the stacked decode state.

    ``length`` (scalar int32, optional): number of real positions when the
    sequence is right-padded; only recurrent families consume it (their
    terminal state must not integrate pad steps). Attention/enc-dec caches
    are position-masked and ignore it.
    """
    x = _add_learned_pos(cfg, params, embeds, positions if positions.ndim == 2 else positions[0])
    angles = make_angles(cfg, positions)
    if cfg.family == "audio":
        memory = _encode_memory(cfg, params, enc_embeds)
        x, state = encdec.dec_prefill(cfg, params, x, memory, capacity)
    else:
        x, state = transformer.prefill_stack(cfg, params, x, angles, capacity,
                                             length=length)
    return state, norm(cfg, params["final_norm"], x)


def decode_step(cfg, params, embed, state, pos):
    """One-token decode. embed (B, 1, D); pos scalar int32.

    Returns (logits (B, 1, V), new state).
    """
    b = embed.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = _add_learned_pos(cfg, params, embed, positions)
    angles = make_angles(cfg, positions)
    if cfg.family == "audio":
        x, state = encdec.dec_step(cfg, params, x, state, pos)
    else:
        x, state = transformer.decode_stack(cfg, params, x, angles, state, pos)
    hidden = norm(cfg, params["final_norm"], x)
    return logits(cfg, params, hidden), state


def init_state(cfg, batch: int, capacity: int, dtype):
    if cfg.family == "audio":
        return encdec.init_dec_state(cfg, batch, capacity, dtype)
    return transformer.init_decode_state(cfg, batch, capacity, dtype)
