"""Modality-frontend STUBS (the one sanctioned carve-out).

For VLM archs the ViT/SigLIP tower, and for audio the mel+conv codec, are not
implemented — ``frame_embeddings``/``patch_embeddings`` return deterministic
pseudo-embeddings of the correct shape/dtype, standing in for "precomputed
frontend output". The frozen *connector* (projection to d_model) and
everything downstream are real.

The synthetic data pipeline (repro.data) also routes through these so the
planted topic structure survives: embeddings are a function of the latent
topic vector, giving 𝒜_I something real to adapt.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def num_patches(cfg) -> int:
    """Patch/frame count fed to the connector for each image/audio clip."""
    if cfg.family == "audio":
        return cfg.enc_seq_len
    if cfg.name.startswith("minigpt4"):
        return 32  # Q-Former emits 32 query embeddings
    return 64  # ViT patch grid after merger (stand-in)


def patch_embeddings(key, cfg, batch: int, dtype=jnp.float32):
    """Deterministic pseudo patch/frame embeddings (B, M, frontend_dim)."""
    m = num_patches(cfg)
    return jax.random.normal(key, (batch, m, cfg.frontend_dim)).astype(dtype)


def topic_patch_embeddings(key, cfg, topic_vecs, dtype=jnp.float32):
    """Patch embeddings whose mean is steered by a per-example topic vector.

    topic_vecs (B, frontend_dim) — the planted cluster structure used by the
    synthetic VQA pipeline so that non-IID topic splits induce real
    visual-representation shift (DESIGN.md §6.1).
    """
    b = topic_vecs.shape[0]
    m = num_patches(cfg)
    noise = jax.random.normal(key, (b, m, cfg.frontend_dim)) * 0.5
    return (topic_vecs[:, None, :] + noise).astype(dtype)
