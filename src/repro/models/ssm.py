"""Mamba2 block (SSD — state space duality), TPU-adapted.

Block structure (arXiv:2405.21060, "parallel" Mamba2 block):

    u -> in_proj -> [z | xBC | dt]
         xBC -> causal depthwise conv1d -> silu -> [x | B | C]
         x:(B,S,H,P)  dt:(B,S,H) -> softplus(dt + dt_bias)
         y = SSD(x·dt, exp(dt·A) decay, B, C) + D ⊙ x
         y -> gated RMSNorm(y, z) -> out_proj

Train/prefill uses the chunked-matmul SSD (Pallas kernel or jnp oracle);
decode carries (conv_state, ssm_state) and does the O(1) recurrence step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ref as ssd_ref
from repro.models.layers import dense_init
from repro.sharding import constrain


class SSMState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_dim) — trailing conv window
    h: jax.Array     # (B, H, P, N) — SSM state


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def init_ssm(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.d_state + H
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.exp(
        jax.random.uniform(k3, (H,)) * (jnp.log(s.dt_max) - jnp.log(s.dt_min))
        + jnp.log(s.dt_min)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(k1, (d, d_in_proj), dtype),
        "conv_w": (jax.random.normal(k2, (s.d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k4, (d_inner, d), dtype, scale=d_inner**-0.5),
    }


def _gated_rmsnorm(scale, y, z, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : 2 * d_inner + 2 * s.d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * s.d_state :]
    return z, xBC, dt


def _causal_conv(params, xBC, cfg):
    """Depthwise causal conv over time. xBC (B, S, conv_dim)."""
    w = params["conv_w"].astype(xBC.dtype)  # (d_conv, conv_dim)
    d_conv = w.shape[0]
    pads = jnp.pad(xBC, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(d_conv):  # d_conv == 4: tiny unrolled loop
        out = out + pads[:, i : i + xBC.shape[1]] * w[i]
    return out + params["conv_b"].astype(xBC.dtype)


def ssm_apply(cfg, params, u, *, use_pallas: bool = False):
    """Full-sequence Mamba2 block. u (B, S, D) -> (B, S, D)."""
    s = cfg.ssm
    B_, S, D = u.shape
    d_inner, H, conv_dim = _dims(cfg)

    zxbcdt = u @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(params, xBC, cfg))
    x = xBC[..., :d_inner].reshape(B_, S, H, s.head_dim)
    x = constrain(x, ("data", None, "model", None))
    Bm = xBC[..., d_inner : d_inner + s.d_state]
    Cm = xBC[..., d_inner + s.d_state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops

        y = ssd_ops.ssd(x, dt.astype(x.dtype), A, Bm, Cm, chunk=s.chunk_size, interpret=True)
    else:
        y = ssd_ref.ssd_chunked(x, dt.astype(x.dtype), A, Bm, Cm, chunk=s.chunk_size)
    y = y + x * params["D"][:, None].astype(x.dtype)
    y = y.reshape(B_, S, d_inner)
    y = _gated_rmsnorm(params["norm_scale"], y, z)
    out = y @ params["out_proj"]
    return constrain(out, ("data", None, None))


def init_ssm_state(cfg, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        h=jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    )


def ssm_prefill(cfg, params, u, length=None):
    """Run full sequence AND return the terminal SSMState for decoding.

    ``length`` (scalar int32, optional) marks only the first ``length``
    positions as real: ``dt`` is zeroed on the tail, which makes the decay
    ``exp(0·A) = 1`` and the input contribution ``0·x = 0`` — pad steps pass
    the recurrent state through *exactly*, so the terminal state equals the
    unpadded run's bit-for-bit (the chunked machinery already relies on this
    identity for its internal chunk padding). The conv tail is sliced at the
    valid length. Serving uses this to prefill right-padded prompts without
    contaminating the SSM state.
    """
    s = cfg.ssm
    B_, S, D = u.shape
    d_inner, H, conv_dim = _dims(cfg)
    zxbcdt = u @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # last (d_conv-1) *valid* inputs; the window before t=0 is zero by the
    # causal-conv convention, so left-extend with zeros — this also keeps
    # prompts shorter than d_conv-1 from yielding a truncated conv window
    zext = jnp.concatenate(
        [jnp.zeros((B_, s.d_conv - 1, conv_dim), xBC.dtype), xBC], axis=1)
    if length is None:
        conv_tail = zext[:, -(s.d_conv - 1) :, :]
    else:
        conv_tail = jax.lax.dynamic_slice_in_dim(
            zext, jnp.asarray(length, jnp.int32), s.d_conv - 1, axis=1)
    xBCc = jax.nn.silu(_causal_conv(params, xBC, cfg))
    x = xBCc[..., :d_inner].reshape(B_, S, H, s.head_dim)
    Bm = xBCc[..., d_inner : d_inner + s.d_state]
    Cm = xBCc[..., d_inner + s.d_state :]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if length is not None:
        valid = jnp.arange(S) < jnp.asarray(length, jnp.int32)
        dtp = jnp.where(valid[None, :, None], dtp, 0.0)
    A = -jnp.exp(params["A_log"])
    y = ssd_ref.ssd_chunked(x, dtp.astype(x.dtype), A, Bm, Cm, chunk=s.chunk_size)
    y = y + x * params["D"][:, None].astype(x.dtype)
    y = _gated_rmsnorm(params["norm_scale"], y.reshape(B_, S, d_inner), z)
    out = y @ params["out_proj"]

    # terminal state: replay the recurrence per-chunk is equivalent to running
    # the sequential reference once over the last state; we compute it exactly
    # with the chunked machinery's final carry.
    h_final = _final_state(x, dtp, A, Bm, Cm, cfg.ssm.chunk_size)
    state = SSMState(conv=conv_tail, h=h_final)
    return out, state


def _final_state(x, dt, A, Bm, Cm, chunk: int):
    """Exact terminal SSM state h_S (B, H, P, N) via the chunked recurrence."""
    Bt, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    pad = (-S) % Q
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        # pad with dt = 0 -> decay 1, update 0: state passes through unchanged
        x, Bm, Cm = zf(x), zf(Bm), zf(Cm)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q
    xf = x.reshape(Bt, nc, Q, H, P).astype(jnp.float32)
    dtf = dt.reshape(Bt, nc, Q, H).astype(jnp.float32)
    Bf = Bm.reshape(Bt, nc, Q, N).astype(jnp.float32)
    la = jnp.moveaxis(dtf * A, -1, 2)  # (Bt, nc, H, Q)
    L = jnp.cumsum(la, axis=-1)
    dec_last = jnp.exp(L[..., -1:] - L)
    xdt = xf * dtf[..., None]
    states = jnp.einsum("bchj,bcjn,bcjhp->bchnp", dec_last, Bf, xdt)
    chunk_decay = jnp.exp(L[..., -1])

    def step(h, inp):
        st, dec = inp
        return h * dec[..., None, None] + st, None

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    h, _ = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    return jnp.swapaxes(h, -1, -2)  # (Bt, H, P, N)


def ssm_decode_step(cfg, params, u, state: SSMState):
    """One-token decode. u (B, 1, D) -> (out (B, 1, D), new state)."""
    s = cfg.ssm
    B_, _, D = u.shape
    d_inner, H, conv_dim = _dims(cfg)
    zxbcdt = u[:, 0] @ params["in_proj"]  # (B, d_in_proj)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([state.conv, xBC[:, None, :]], axis=1)  # (B, d_conv, conv_dim)
    w = params["conv_w"].astype(xBC.dtype)
    conv_out = jnp.sum(window * w[None], axis=1) + params["conv_b"].astype(xBC.dtype)
    xBCc = jax.nn.silu(conv_out)
    x = xBCc[..., :d_inner].reshape(B_, H, s.head_dim)
    Bm = xBCc[..., d_inner : d_inner + s.d_state]
    Cm = xBCc[..., d_inner + s.d_state :]
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    y, h_new = ssd_ref.ssd_decode_step(state.h, x, dtp, A, Bm, Cm)
    y = y + x * params["D"][:, None].astype(x.dtype)
    y = _gated_rmsnorm(params["norm_scale"], y.reshape(B_, d_inner), z)
    out = (y @ params["out_proj"])[:, None, :]
    new_state = SSMState(conv=window[:, 1:], h=h_new)
    return constrain(out, ("data", None, None)), new_state
