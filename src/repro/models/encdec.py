"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is STUBBED per the assignment carve-out — the encoder
consumes precomputed frame embeddings (B, enc_seq, frontend_dim) through the
frozen connector. Everything downstream is real: bidirectional encoder,
causal decoder with self-KV cache + precomputed cross-KV, learned positions.

NanoEdge attachment (see repro.core.adapters): 𝒜_I adapts the frame
embeddings before the encoder; 𝒜_T adapts decoder token embeddings.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import init_mlp, init_norm, mlp, norm
from repro.models.attention import KVCache


class DecLayerState(NamedTuple):
    self_kv: KVCache
    cross_kv: KVCache  # fixed after prefill


def init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "self_attn": attn_lib.init_attention(k1, cfg, dtype=dtype),
        "norm_x": init_norm(cfg, cfg.d_model, dtype),
        "cross_attn": attn_lib.init_attention(k2, cfg, cross=True, dtype=dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg, dtype=dtype),
    }


def init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype=dtype),
        "norm2": init_norm(cfg, cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg, dtype=dtype),
    }


def init_encdec_stacks(key, cfg, dtype):
    ke, kd = jax.random.split(key)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(
        jax.random.split(ke, cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(
        jax.random.split(kd, cfg.n_layers)
    )
    return {"enc_layers": enc, "dec_layers": dec}


def encode(cfg, stacks, x):
    """Bidirectional encoder. x (B, M, D) frame embeddings (+pos added upstream)."""

    def body(c, lp):
        h = norm(cfg, lp["norm1"], c)
        c = c + attn_lib.full_attention(cfg, lp["attn"], h, None, causal=False)
        c = c + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], c))
        return c, None

    f = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, stacks["enc_layers"])
    return x


def _dec_body(cfg, lp, x, memory):
    h = norm(cfg, lp["norm1"], x)
    x = x + attn_lib.full_attention(cfg, lp["self_attn"], h, None, causal=True)
    h = norm(cfg, lp["norm_x"], x)
    x = x + attn_lib.full_attention(cfg, lp["cross_attn"], h, None, memory=memory)
    x = x + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], x))
    return x


def decode_forward(cfg, stacks, x, memory):
    """Teacher-forced decoder over the full target sequence."""

    def body(c, lp):
        return _dec_body(cfg, lp, c, memory), None

    f = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(f, x, stacks["dec_layers"])
    return x, jnp.float32(0.0)


def dec_prefill(cfg, stacks, x, memory, capacity: int):
    """Teacher-forced pass that also builds decode state (self KV + cross KV)."""

    def body(c, lp):
        h = norm(cfg, lp["norm1"], c)
        out, (k, v) = attn_lib.full_attention(
            cfg, lp["self_attn"], h, None, causal=True, return_kv=True
        )
        c = c + out
        self_kv = attn_lib.init_cache(cfg, c.shape[0], capacity, c.dtype)
        self_kv = attn_lib.seed_cache(cfg, self_kv, k, v, start=0)
        h = norm(cfg, lp["norm_x"], c)
        out, (ck, cv) = attn_lib.full_attention(
            cfg, lp["cross_attn"], h, None, memory=memory, return_kv=True
        )
        c = c + out
        c = c + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], c))
        return c, DecLayerState(self_kv=self_kv, cross_kv=KVCache(ck, cv))

    x, states = jax.lax.scan(body, x, stacks["dec_layers"])
    return x, {"layers": states}


def dec_step(cfg, stacks, x, state, pos):
    """One-token decode. x (B, 1, D)."""

    def body(c, inp):
        lp, st = inp
        h = norm(cfg, lp["norm1"], c)
        out, self_kv = attn_lib.decode_attention(cfg, lp["self_attn"], h, None, st.self_kv, pos)
        c = c + out
        h = norm(cfg, lp["norm_x"], c)
        c = c + attn_lib.cross_decode_attention(cfg, lp["cross_attn"], h, st.cross_kv)
        c = c + mlp(cfg, lp["mlp"], norm(cfg, lp["norm2"], c))
        return c, DecLayerState(self_kv=self_kv, cross_kv=st.cross_kv)

    x, states = jax.lax.scan(body, x, (stacks["dec_layers"], state["layers"]))
    return x, {"layers": states}


def init_dec_state(cfg, batch: int, capacity: int, dtype):
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            DecLayerState(
                self_kv=attn_lib.init_cache(cfg, batch, capacity, dtype),
                cross_kv=attn_lib.init_cache(cfg, batch, cfg.enc_seq_len, dtype),
            )
        )
    return {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers)}
