"""Checkpointing: pytree <-> npz with path-keyed entries (no orbax offline).

Saves any params/opt-state pytree; restores require the reference structure
(standard practice — the training script always has it). Restores are
*strict*: a leaf whose shape or dtype differs from the reference raises
instead of silently casting (a checkpoint saved at a different precision
must be converted deliberately, never on load), and unexpected extra keys
are rejected unless ``strict=False``.

Server + client states round-trip through ``save_server_checkpoint`` /
``load_server_checkpoint``; full engine state (ServerOpt moments, per-client
optimizer state, transform residuals, round RNG, CommLog) goes through
``repro.checkpoint.run_state``. Every on-disk format carries a
``format_version`` in ``meta.json``; mismatches raise
:class:`CheckpointVersionError` rather than mis-restoring.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Mapping, Optional

import jax
import numpy as np

# On-disk format of save_server_checkpoint. v1 (implicit, no version field)
# dropped ServerOpt moments and the round RNG on the floor — a "resumed" run
# silently restarted the server optimizer from zero. v2 persists both and
# stamps the version so stale checkpoints fail loudly.
SERVER_CHECKPOINT_VERSION = 2


class CheckpointError(ValueError):
    """A checkpoint could not be restored (corrupt, incomplete, mismatched)."""


class CheckpointVersionError(CheckpointError):
    """The checkpoint's on-disk format version doesn't match this code."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def flatten_pytree(tree, *, prefix: str = "") -> Dict[str, np.ndarray]:
    """Flatten a pytree to ``{path: np.ndarray}`` (the npz entry layout).

    A non-empty ``prefix`` namespaces the keys (``prefix/leafpath``) so many
    pytrees can share one archive — the ``RunState`` format builds on this.
    A pytree that is a single bare array maps to the prefix itself.
    """
    flat = _flatten(tree)
    if not prefix:
        return flat
    return {f"{prefix}/{k}" if k else prefix: v for k, v in flat.items()}


def unflatten_pytree(reference, data: Mapping[str, np.ndarray], *,
                     prefix: str = "", where: str = "checkpoint"):
    """Rebuild ``reference``'s structure from path-keyed arrays.

    Shape AND dtype of every leaf must match the reference exactly —
    restoring a checkpoint saved at a different precision through a silent
    cast corrupts optimizer moments and DP noise scales, so it is an error.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for p, ref_leaf in flat:
        key = "/".join(_path_str(q) for q in p)
        if prefix:
            key = f"{prefix}/{key}" if key else prefix
        if key not in data:
            raise CheckpointError(f"{where} missing key {key!r}")
        arr = data[key]
        ref_arr = np.asarray(ref_leaf)
        if tuple(arr.shape) != tuple(ref_arr.shape):
            raise CheckpointError(
                f"shape mismatch for {key}: {where} has {arr.shape}, "
                f"reference expects {ref_arr.shape}")
        if arr.dtype != ref_arr.dtype:
            raise CheckpointError(
                f"dtype mismatch for {key}: {where} holds {arr.dtype}, "
                f"reference expects {ref_arr.dtype}; convert the checkpoint "
                "explicitly instead of relying on a silent cast")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), leaves)


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flatten_pytree(tree))


def load_pytree(path: str, reference, *, strict: bool = True):
    """Restore into the structure of ``reference`` (shapes/dtypes enforced).

    ``strict=True`` (default) also rejects archives carrying keys the
    reference doesn't know about — an extra key means the file was written
    against a different structure, and half-matching it hides real drift.
    """
    data = np.load(path, allow_pickle=False)
    restored = unflatten_pytree(reference, data, where=os.path.basename(path))
    if strict:
        expected = set(flatten_pytree(reference))
        extra = sorted(set(data.files) - expected)
        if extra:
            raise CheckpointError(
                f"{os.path.basename(path)} carries keys not in the reference "
                f"structure: {extra[:5]}{'...' if len(extra) > 5 else ''} "
                "(pass strict=False to ignore)")
    return restored


def load_adapters(path: str, reference):
    """Restore a NanoAdapter pytree for serving/hot-swap.

    ``path`` is either a bare ``.npz`` written by :func:`save_pytree`, or a
    :func:`save_server_checkpoint` directory — in that case only
    ``global_adapters.npz`` is read (the serving engine never needs the
    backbone copy: it is frozen and shared across tenants by construction).
    """
    if os.path.isdir(path):
        inner = os.path.join(path, "global_adapters.npz")
        if not os.path.exists(inner):
            raise CheckpointError(
                f"{path!r} is a directory without global_adapters.npz — not "
                "a server checkpoint")
        return load_pytree(inner, reference)
    if not os.path.exists(path):
        raise CheckpointError(f"no adapter checkpoint at {path!r}")
    return load_pytree(path, reference)


def _key_data(key) -> Optional[np.ndarray]:
    """Raw uint32 data of a PRNG key (old-style arrays pass through)."""
    if key is None:
        return None
    try:
        if jax.numpy.issubdtype(key.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(key))
    except (AttributeError, TypeError):
        pass
    return np.asarray(key)


def save_server_checkpoint(dirpath: str, server, round_idx: int, *,
                           server_opt_state=None, rng_key=None) -> None:
    """Persist a server snapshot: backbone, global adapters, CommLog, and —
    the pieces v1 silently dropped — the ServerOpt moments and round RNG."""
    os.makedirs(dirpath, exist_ok=True)
    save_pytree(os.path.join(dirpath, "backbone.npz"), server.backbone)
    save_pytree(os.path.join(dirpath, "global_adapters.npz"),
                server.global_adapters)
    if server_opt_state is not None:
        save_pytree(os.path.join(dirpath, "server_opt_state.npz"),
                    server_opt_state)
    kd = _key_data(rng_key)
    if kd is not None:
        np.savez(os.path.join(dirpath, "rng_key.npz"), rng_key=kd)
    meta = {
        "format_version": SERVER_CHECKPOINT_VERSION,
        "round_idx": round_idx,
        "cfg_name": server.cfg.name,
        "server_round_idx": server.round_idx,
        "has_server_opt_state": server_opt_state is not None,
        "has_rng_key": kd is not None,
        "comm_rounds": [r.to_dict() for r in server.comm.rounds],
    }
    # meta.json is written last: a checkpoint without it is unreadable by
    # design, so a crash mid-save never yields a half-restorable directory
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_server_checkpoint(dirpath: str, server, *, server_opt_state=None):
    """Restore a server snapshot saved by :func:`save_server_checkpoint`.

    ``server_opt_state`` is the *reference* structure for the ServerOpt
    moments (``server_opt.init(global_adapters)``); when the checkpoint has
    moments they are returned under ``meta["server_opt_state"]`` (and the
    restored RNG key, if any, under ``meta["rng_key"]``). Checkpoints from a
    different format version raise :class:`CheckpointVersionError` — v1
    checkpoints never stored the optimizer moments, so "restoring" one into
    a FedOpt run would silently zero the server momentum.
    """
    import dataclasses

    from repro.core.comm import CommLog, RoundTraffic

    meta_path = os.path.join(dirpath, "meta.json")
    if not os.path.exists(meta_path):
        raise CheckpointError(f"no checkpoint at {dirpath!r} (meta.json missing)")
    with open(meta_path) as f:
        meta = json.load(f)
    version = meta.get("format_version")
    if version != SERVER_CHECKPOINT_VERSION:
        raise CheckpointVersionError(
            f"checkpoint at {dirpath!r} has format_version={version!r}, this "
            f"code reads v{SERVER_CHECKPOINT_VERSION}; older checkpoints "
            "lack the ServerOpt moments / round RNG and cannot be resumed "
            "faithfully — re-save with the current code")
    backbone = load_pytree(os.path.join(dirpath, "backbone.npz"),
                           server.backbone)
    adapters = load_pytree(os.path.join(dirpath, "global_adapters.npz"),
                           server.global_adapters)
    comm = CommLog(rounds=[RoundTraffic.from_dict(d)
                           for d in meta.get("comm_rounds", [])])
    if meta.get("has_server_opt_state"):
        if server_opt_state is None:
            raise CheckpointError(
                f"checkpoint at {dirpath!r} carries ServerOpt moments; pass "
                "the reference structure via server_opt_state= (e.g. "
                "server_opt.init(global_adapters)) so they are not dropped")
        meta["server_opt_state"] = load_pytree(
            os.path.join(dirpath, "server_opt_state.npz"), server_opt_state)
    if meta.get("has_rng_key"):
        meta["rng_key"] = np.load(
            os.path.join(dirpath, "rng_key.npz"))["rng_key"]
    return dataclasses.replace(
        server, backbone=backbone, global_adapters=adapters, comm=comm,
        round_idx=meta.get("server_round_idx", meta["round_idx"]),
    ), meta
