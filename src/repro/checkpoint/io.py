"""Checkpointing: pytree <-> npz with path-keyed entries (no orbax offline).

Saves any params/opt-state pytree; restores require the reference structure
(standard practice — the training script always has it). Server + client
states round-trip through ``save_server_checkpoint``/``load_server_checkpoint``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_pytree(path: str, reference):
    """Restore into the structure of ``reference`` (dtypes/shapes checked)."""
    data = np.load(path, allow_pickle=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for p, ref_leaf in flat:
        key = "/".join(_path_str(q) for q in p)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(ref_leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(ref_leaf)}")
        leaves.append(jax.numpy.asarray(arr, dtype=np.asarray(ref_leaf).dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(reference), leaves)


def save_server_checkpoint(dirpath: str, server, round_idx: int) -> None:
    os.makedirs(dirpath, exist_ok=True)
    save_pytree(os.path.join(dirpath, "backbone.npz"), server.backbone)
    save_pytree(os.path.join(dirpath, "global_adapters.npz"), server.global_adapters)
    meta = {"round_idx": round_idx, "cfg_name": server.cfg.name}
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_server_checkpoint(dirpath: str, server):
    import dataclasses

    backbone = load_pytree(os.path.join(dirpath, "backbone.npz"), server.backbone)
    adapters = load_pytree(os.path.join(dirpath, "global_adapters.npz"), server.global_adapters)
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    return dataclasses.replace(
        server, backbone=backbone, global_adapters=adapters, round_idx=meta["round_idx"]
    ), meta
