from repro.checkpoint.io import (
    CheckpointError,
    CheckpointVersionError,
    SERVER_CHECKPOINT_VERSION,
    flatten_pytree,
    load_pytree,
    load_server_checkpoint,
    save_pytree,
    save_server_checkpoint,
    unflatten_pytree,
)
from repro.checkpoint.run_state import (
    RUN_STATE_VERSION,
    BufferedState,
    RunState,
    load_run_state,
    read_run_meta,
    resolve_run_state_dir,
    save_run_state,
)

__all__ = [
    "CheckpointError",
    "CheckpointVersionError",
    "SERVER_CHECKPOINT_VERSION",
    "RUN_STATE_VERSION",
    "BufferedState",
    "RunState",
    "flatten_pytree",
    "load_pytree",
    "load_run_state",
    "load_server_checkpoint",
    "read_run_meta",
    "resolve_run_state_dir",
    "save_pytree",
    "save_run_state",
    "save_server_checkpoint",
    "unflatten_pytree",
]
