from repro.checkpoint.io import (
    load_pytree,
    load_server_checkpoint,
    save_pytree,
    save_server_checkpoint,
)

__all__ = [
    "load_pytree",
    "load_server_checkpoint",
    "save_pytree",
    "save_server_checkpoint",
]
