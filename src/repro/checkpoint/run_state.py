"""Full round-state snapshots: everything a federated run needs to resume.

``save_server_checkpoint`` persists the *model*; a killed run also loses the
ServerOpt moments, every client's AdamW state and error-feedback residuals,
the sampler/failure RNG derivation, the CommLog, and — for the buffered
async engine — the in-flight event queue and version snapshots. ``RunState``
captures all of it so "run R rounds" and "run r, kill, resume, run R−r"
are indistinguishable (the resume-equivalence suite in
``tests/test_resume.py`` pins this to 1e-6 on every metric).

On-disk layout (one directory per snapshot):

    meta.json       format_version, engine/strategy/hp identity, per-client
                    presence flags, round metrics, comm log, buffered-engine
                    bookkeeping (event heap, refcounts), and a nonce
    run_state.npz   every array leaf, path-keyed under fixed prefixes:
                      rng_key                  root PRNG key (uint32 data)
                      global/...               θ_global
                      sopt/...                 ServerOpt moments
                      client/<i>/adapters/...  per-client trees (opt/, local/,
                                               lopt/, fisher/ alongside)
                      tstate/<i>/<j>/...       transform residuals
                      bsnap/<v>/...            buffered: live version globals
                      bbuf/<n>/theta|fisher/.. buffered: unmerged uploads
                      __nonce__                torn-write detector

``meta.json`` is written last and carries the same nonce as the npz: a
crash mid-save leaves either no meta (unreadable by design) or a nonce
mismatch (rejected), never a half-restored run. The golden fixture under
``tests/golden/run_state/`` pins this layout so format changes are
deliberate (bump ``RUN_STATE_VERSION``).

Restores go through reference structures (the training script re-derives
them from the same seed/cfg) with strict shape+dtype checks — see
``repro.checkpoint.io``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint.io import (
    CheckpointError,
    CheckpointVersionError,
    flatten_pytree,
    unflatten_pytree,
)

RUN_STATE_VERSION = 1

_NONCE_KEY = "__nonce__"


@dataclass
class BufferedState:
    """Buffered-engine bookkeeping at a tick boundary.

    ``events`` is the completion heap *as a list* — a valid heap restored
    verbatim pops in the identical order, so the resumed event loop replays
    the uninterrupted one exactly. ``snapshots`` maps live global versions
    to (adapters, in-flight refcount); ``buffer`` holds uploads awaiting the
    next merge as (theta, fisher, n_examples, loss_mean, staleness).
    """

    version: int = 0
    events: List[tuple] = field(default_factory=list)
    snapshots: Dict[int, list] = field(default_factory=dict)
    buffer: List[tuple] = field(default_factory=list)
    acc_up: Dict[str, int] = field(default_factory=dict)


@dataclass
class RunState:
    """A complete, versioned snapshot of a ``run_federated`` run."""

    engine: str
    strategy: str
    round_idx: int                 # rounds completed (sync) / merges (buffered)
    server_round_idx: int          # ServerState.round_idx (commit counter)
    rng_key: Any                   # root PRNG key data (resume identity check)
    global_adapters: Any
    server_opt_state: Any = None
    clients: List[Any] = field(default_factory=list)   # ClientState list
    tstates: List[List[Any]] = field(default_factory=list)  # [client][transform]
    round_metrics: List[dict] = field(default_factory=list)
    comm_rounds: List[dict] = field(default_factory=list)
    buffered: Optional[BufferedState] = None
    meta_extra: Dict[str, Any] = field(default_factory=dict)  # hp, cfg, ...


def _client_meta(c) -> dict:
    return {
        "cid": c.cid,
        "n_examples": c.n_examples,
        "rounds_participated": c.rounds_participated,
        "has_fisher": c.fisher is not None,
        "has_local": c.local_adapters is not None,
        "has_local_opt": c.local_opt_state is not None,
    }


def save_run_state(dirpath: str, rs: RunState) -> None:
    os.makedirs(dirpath, exist_ok=True)
    nonce = f"{rs.engine}:{rs.round_idx}:{rs.server_round_idx}:{len(rs.comm_rounds)}"

    arrays: Dict[str, np.ndarray] = {}

    def put(prefix, tree):
        if tree is not None:
            arrays.update(flatten_pytree(tree, prefix=prefix))

    put("rng_key", np.asarray(rs.rng_key))
    put("global", rs.global_adapters)
    put("sopt", rs.server_opt_state)
    for i, c in enumerate(rs.clients):
        put(f"client/{i}/adapters", c.adapters)
        put(f"client/{i}/opt", c.opt_state)
        put(f"client/{i}/local", c.local_adapters)
        put(f"client/{i}/lopt", c.local_opt_state)
        put(f"client/{i}/fisher", c.fisher)
    for i, per_client in enumerate(rs.tstates):
        for j, st in enumerate(per_client):
            put(f"tstate/{i}/{j}", st)

    buffered_meta = None
    if rs.buffered is not None:
        b = rs.buffered
        for v, (snap, refcount) in sorted(b.snapshots.items()):
            put(f"bsnap/{v}", snap)
        buf_meta = []
        for n, (theta, fisher, n_ex, loss, stale) in enumerate(b.buffer):
            put(f"bbuf/{n}/theta", theta)
            put(f"bbuf/{n}/fisher", fisher)
            buf_meta.append({"n_examples": int(n_ex), "loss_mean": float(loss),
                             "staleness": int(stale),
                             "has_fisher": fisher is not None})
        buffered_meta = {
            "version": b.version,
            "events": [list(e) for e in b.events],
            "snapshot_refcounts": {str(v): int(rc)
                                   for v, (_, rc) in b.snapshots.items()},
            "buffer": buf_meta,
            "acc_up": dict(b.acc_up),
        }

    arrays[_NONCE_KEY] = np.frombuffer(nonce.encode(), dtype=np.uint8)
    np.savez(os.path.join(dirpath, "run_state.npz"), **arrays)

    meta = {
        "format_version": RUN_STATE_VERSION,
        "nonce": nonce,
        "engine": rs.engine,
        "strategy": rs.strategy,
        "round_idx": rs.round_idx,
        "server_round_idx": rs.server_round_idx,
        "n_clients": len(rs.clients),
        "clients": [_client_meta(c) for c in rs.clients],
        "n_transforms": len(rs.tstates[0]) if rs.tstates else 0,
        "tstate_present": [[st is not None for st in per_client]
                           for per_client in rs.tstates],
        "has_server_opt_state": rs.server_opt_state is not None,
        "round_metrics": rs.round_metrics,
        "comm_rounds": rs.comm_rounds,
        "buffered": buffered_meta,
    }
    meta.update(rs.meta_extra)
    # meta.json last: no meta, no checkpoint (crash-safe by construction)
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)


def read_run_meta(dirpath: str) -> dict:
    """Load and version-check a snapshot's meta.json (arrays untouched)."""
    meta_path = os.path.join(dirpath, "meta.json")
    if not os.path.exists(meta_path):
        raise CheckpointError(
            f"no run-state checkpoint at {dirpath!r} (meta.json missing)")
    with open(meta_path) as f:
        meta = json.load(f)
    version = meta.get("format_version")
    if version != RUN_STATE_VERSION:
        raise CheckpointVersionError(
            f"run-state checkpoint at {dirpath!r} has "
            f"format_version={version!r}, this code reads "
            f"v{RUN_STATE_VERSION}; refusing to mis-restore")
    return meta


def resolve_run_state_dir(path: str) -> str:
    """Accept either a snapshot directory or a checkpoint root with LATEST."""
    if os.path.exists(os.path.join(path, "meta.json")):
        return path
    latest = os.path.join(path, "LATEST")
    if os.path.exists(latest):
        with open(latest) as f:
            name = f.read().strip()
        cand = os.path.join(path, name)
        if os.path.exists(os.path.join(cand, "meta.json")):
            return cand
        raise CheckpointError(
            f"{latest} points at {name!r} but {cand!r} has no meta.json")
    raise CheckpointError(
        f"no run-state checkpoint at {path!r} (neither meta.json nor LATEST)")


def load_run_state(
    dirpath: str,
    *,
    clients_ref: Sequence[Any],
    global_ref,
    server_opt_state_ref=None,
    transform_templates: Optional[Sequence[Any]] = None,
) -> RunState:
    """Restore a :class:`RunState` against freshly-initialized references.

    ``clients_ref`` are the ClientStates a fresh run would build (same seed,
    same strategy) — they provide the structures; every leaf is overwritten.
    ``transform_templates[j]`` is ``transforms[j].state_template(global)``.
    Optional pieces (fisher, personal-adapter optimizer, transform
    residuals) are restored per the presence flags recorded at save time.
    """
    import jax

    from repro.core.client import client_ref_like

    meta = read_run_meta(dirpath)
    data = np.load(os.path.join(dirpath, "run_state.npz"), allow_pickle=False)

    nonce = bytes(data[_NONCE_KEY]).decode() if _NONCE_KEY in data else None
    if nonce != meta.get("nonce"):
        raise CheckpointError(
            f"torn checkpoint at {dirpath!r}: meta.json nonce "
            f"{meta.get('nonce')!r} != archive nonce {nonce!r} (the save was "
            "interrupted between the two files)")

    if len(clients_ref) != meta["n_clients"]:
        raise CheckpointError(
            f"checkpoint at {dirpath!r} holds {meta['n_clients']} clients, "
            f"the run was set up with {len(clients_ref)}")

    where = os.path.basename(dirpath.rstrip(os.sep)) or dirpath

    def get(prefix, ref):
        return unflatten_pytree(ref, data, prefix=prefix, where=where)

    rng_key = np.asarray(data["rng_key"])
    global_adapters = get("global", global_ref)

    server_opt_state = None
    if meta["has_server_opt_state"]:
        if server_opt_state_ref is None:
            raise CheckpointError(
                f"checkpoint at {dirpath!r} carries ServerOpt moments but no "
                "reference structure was provided — resuming without them "
                "would silently reset the server optimizer")
        server_opt_state = get("sopt", server_opt_state_ref)

    clients = []
    for i, (cref, cmeta) in enumerate(zip(clients_ref, meta["clients"])):
        if cref.cid != cmeta["cid"]:
            raise CheckpointError(
                f"client {i} mismatch: checkpoint cid {cmeta['cid']}, "
                f"reference cid {cref.cid} (different data partition?)")
        if cmeta["has_local"] != (cref.local_adapters is not None):
            raise CheckpointError(
                f"client {cmeta['cid']}: checkpoint "
                f"{'has' if cmeta['has_local'] else 'lacks'} personal "
                "adapters but the configured strategy disagrees")
        ref = client_ref_like(cref)
        clients.append(dataclasses.replace(
            cref,
            adapters=get(f"client/{i}/adapters", ref.adapters),
            opt_state=get(f"client/{i}/opt", ref.opt_state),
            local_adapters=(get(f"client/{i}/local", ref.local_adapters)
                            if cmeta["has_local"] else None),
            local_opt_state=(get(f"client/{i}/lopt", ref.local_opt_state)
                             if cmeta["has_local_opt"] else None),
            fisher=(get(f"client/{i}/fisher", ref.fisher)
                    if cmeta["has_fisher"] else None),
            rounds_participated=cmeta["rounds_participated"],
            n_examples=cmeta["n_examples"],
        ))

    tstates: List[List[Any]] = []
    for i, present in enumerate(meta["tstate_present"]):
        per_client: List[Any] = []
        for j, has in enumerate(present):
            if not has:
                per_client.append(None)
                continue
            tmpl = (transform_templates[j]
                    if transform_templates is not None
                    and j < len(transform_templates) else None)
            if tmpl is None:
                raise CheckpointError(
                    f"checkpoint at {dirpath!r} carries state for transform "
                    f"#{j} but the transform provides no state_template(); "
                    "implement it to make the transform resumable")
            per_client.append(get(f"tstate/{i}/{j}", tmpl))
        tstates.append(per_client)

    buffered = None
    if meta.get("buffered") is not None:
        bm = meta["buffered"]
        fisher_tmpl = client_ref_like(clients_ref[0]).fisher
        snapshots = {}
        for v_str, rc in bm["snapshot_refcounts"].items():
            v = int(v_str)
            snapshots[v] = [get(f"bsnap/{v}", global_ref), rc]
        buffer = []
        for n, ent in enumerate(bm["buffer"]):
            theta = get(f"bbuf/{n}/theta", global_ref)
            fisher = (get(f"bbuf/{n}/fisher", fisher_tmpl)
                      if ent["has_fisher"] else None)
            buffer.append((theta, fisher, ent["n_examples"],
                           ent["loss_mean"], ent["staleness"]))
        buffered = BufferedState(
            version=bm["version"],
            events=[tuple(e) for e in bm["events"]],
            snapshots=snapshots,
            buffer=buffer,
            acc_up=dict(bm["acc_up"]),
        )

    return RunState(
        engine=meta["engine"],
        strategy=meta["strategy"],
        round_idx=meta["round_idx"],
        server_round_idx=meta["server_round_idx"],
        rng_key=rng_key,
        global_adapters=global_adapters,
        server_opt_state=server_opt_state,
        clients=clients,
        tstates=tstates,
        round_metrics=meta["round_metrics"],
        comm_rounds=meta["comm_rounds"],
        buffered=buffered,
        meta_extra={k: v for k, v in meta.items()
                    if k not in {"format_version", "nonce", "engine",
                                 "strategy", "round_idx", "server_round_idx",
                                 "n_clients", "clients", "n_transforms",
                                 "tstate_present", "has_server_opt_state",
                                 "round_metrics", "comm_rounds", "buffered"}},
    )
