"""Mesh-aware sharding constraints usable from model code.

Model code calls ``constrain(x, ("data", None, "model"))`` with *logical* axis
names. When no mesh is active (unit tests, CPU smoke runs) this is an
identity; under ``use_mesh(mesh)`` (set by the launcher / dry-run) it becomes
``jax.lax.with_sharding_constraint`` — axis names that don't exist on the
active mesh are dropped, and axes whose dimension size does not divide evenly
are dropped too (DESIGN.md §5 fallback rules: qwen1.5 20 heads, glm4 kv=2,
odd vocabs).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Axis name of the 1-D federated-cohort mesh: stacked per-client pytrees are
# partitioned along their leading (client) axis over this axis.
CLIENT_AXIS = "clients"


def client_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``("clients",)`` mesh over the first ``n_devices`` local devices.

    The sharded round engine partitions stacked per-client cohort pytrees
    over this axis with ``shard_map`` (``repro.core.client``). Defaults to
    every visible device; on CPU force a multi-device topology with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes.
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"client_mesh needs >= 1 device, got {n}")
    if n > len(devs):
        raise ValueError(
            f"client_mesh(n_devices={n}) but only {len(devs)} devices are "
            "visible — on CPU, set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before the first jax import")
    return Mesh(np.array(devs[:n]), (CLIENT_AXIS,))


def pad_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n`` (cohort padding width)."""
    if m < 1:
        raise ValueError(f"multiple must be >= 1, got {m}")
    return -(-n // m) * m


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate ``mesh`` for :func:`constrain` calls in model code.

    We track the mesh in a thread-local (rather than entering a global jax
    mesh context) — ``with_sharding_constraint`` takes a ``NamedSharding``
    that carries its own mesh, so no ambient context is required and unit
    tests stay unaffected.
    """
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


AxisName = Union[None, str, Tuple[str, ...]]

# Logical-axis aliases: model code says "data" for the batch axis; on the
# multi-pod mesh batch parallelism spans ("pod", "data"). The resolver
# expands the alias and then drops whatever axes the active mesh lacks.
AXIS_ALIASES = {"data": ("pod", "data")}


def _filter_axes(mesh: Mesh, dim_size: int, axes: AxisName) -> AxisName:
    """Expand aliases, drop axes absent from the mesh; drop if non-divisible."""
    if axes is None:
        return None
    tup = (axes,) if isinstance(axes, str) else tuple(axes)
    expanded = []
    for a in tup:
        repl = AXIS_ALIASES.get(a, (a,))
        expanded.extend(repl if isinstance(repl, tuple) else (repl,))
    # de-dup while preserving order (alias expansion can repeat "data")
    seen = set()
    tup = tuple(a for a in expanded if not (a in seen or seen.add(a)))
    tup = tuple(a for a in tup if a in mesh.axis_names)
    if not tup:
        return None
    total = 1
    for a in tup:
        total *= mesh.shape[a]
    if dim_size % total != 0:
        return None
    return tup if len(tup) > 1 else tup[0]


def resolve_spec(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisName]) -> P:
    assert len(shape) == len(spec), (shape, spec)
    return P(*[_filter_axes(mesh, d, a) for d, a in zip(shape, spec)])


def constrain(x, spec: Sequence[AxisName]):
    """Soft sharding constraint with logical axis names (identity w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if x.ndim != len(spec):
        return x
    p = resolve_spec(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, p))


def named_sharding(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisName]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, shape, spec))


def residual_spec(cfg):
    """Sharding of the (B, S, D) residual stream between blocks.

    seq_parallel=True (Megatron-SP, DESIGN/EXPERIMENTS §Perf): sequence over
    the model axis — partial-sum block outputs lower to reduce-scatter and
    block inputs to all-gather (both bf16) instead of full fp32 all-reduces,
    and the fp32 norm arithmetic runs on 1/model_size of the tokens.
    """
    if getattr(cfg, "seq_parallel", False):
        return ("data", "model", None)
    return ("data", None, None)
