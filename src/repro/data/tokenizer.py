"""Toy deterministic tokenizer for the synthetic VQA corpus.

Fixed id layout (low ids are special so any vocab ≥ 64 works, including the
reduced smoke vocab of 512):

    0 PAD   1 BOS   2 EOS   3 Q_START   4 Q_END   5 ANS_SEP
    [8,  8+n_topic_words)   topic keywords
    [40, 40+n_answers)      answer tokens
    [64, vocab)             filler words (hash bucket)
"""
from __future__ import annotations

from dataclasses import dataclass

PAD, BOS, EOS, Q_START, Q_END, ANS_SEP = 0, 1, 2, 3, 4, 5
TOPIC_BASE = 8
ANSWER_BASE = 40
FILLER_BASE = 64


@dataclass(frozen=True)
class ToyTokenizer:
    vocab_size: int
    n_topics: int = 8
    n_answers: int = 16

    def topic_token(self, topic: int) -> int:
        return TOPIC_BASE + (topic % self.n_topics)

    def answer_token(self, answer: int) -> int:
        return ANSWER_BASE + (answer % self.n_answers)

    def filler_token(self, h: int) -> int:
        span = max(self.vocab_size - FILLER_BASE, 1)
        return FILLER_BASE + (h % span)

    def is_answer(self, tok: int) -> bool:
        return ANSWER_BASE <= tok < ANSWER_BASE + self.n_answers

    def decode_answer(self, tok: int) -> int:
        return tok - ANSWER_BASE
