"""Dirichlet non-IID partitioning (paper §4.1; Che et al. 2023; Lai et al. 2022).

For each topic (ScienceQA topic / IconQA skill analogue), sample a
distribution over the K clients from Dir(α·1_K) and split that topic's
examples proportionally. Small α ⇒ each topic concentrates on few clients
(strongly non-IID); large α ⇒ near-uniform (near-IID). The paper uses
α ∈ {0.1, 1, 5} with α=1 as the main setting.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def dirichlet_partition(
    items: Sequence,
    topics: Sequence[int],
    n_clients: int,
    alpha: float,
    seed: int = 0,
    min_per_client: int = 2,
) -> Dict[int, List]:
    """Partition ``items`` (with per-item topic labels) across clients."""
    rng = np.random.RandomState(seed)
    topics = np.asarray(topics)
    uniq = np.unique(topics)
    shards: Dict[int, List] = {k: [] for k in range(n_clients)}

    for t in uniq:
        idx = np.where(topics == t)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(alpha * np.ones(n_clients))
        # proportional contiguous split
        counts = np.floor(p * len(idx)).astype(int)
        while counts.sum() < len(idx):
            counts[rng.randint(n_clients)] += 1
        start = 0
        for k in range(n_clients):
            for i in idx[start : start + counts[k]]:
                shards[k].append(items[i])
            start += counts[k]

    # guarantee a floor so every client can form at least one batch
    donors = sorted(shards, key=lambda k: -len(shards[k]))
    for k in range(n_clients):
        while len(shards[k]) < min_per_client:
            d = donors[0]
            if len(shards[d]) <= min_per_client:
                break
            shards[k].append(shards[d].pop())
            donors = sorted(shards, key=lambda q: -len(shards[q]))
    for k in shards:
        rng.shuffle(shards[k])
    return shards


def partition_stats(shards: Dict[int, List], topic_of) -> Dict[int, Dict[int, int]]:
    """client -> topic -> count (for heterogeneity reporting)."""
    out = {}
    for k, items in shards.items():
        hist: Dict[int, int] = {}
        for it in items:
            t = topic_of(it)
            hist[t] = hist.get(t, 0) + 1
        out[k] = hist
    return out
