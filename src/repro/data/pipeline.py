"""Batching pipeline: Examples -> jnp Batches, per-client train/val/test.

Deterministic, dependency-free (no tf.data offline); batches are
materialized as device arrays once and reused across rounds — the realistic
choice for few-hundred-example client shards.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.types import Batch
from repro.data.synthetic import Example, SyntheticVQA
from repro.data.partition import dirichlet_partition


def examples_to_batches(examples: List[Example], batch_size: int, *, drop_remainder: bool = False) -> List[Batch]:
    out = []
    n = len(examples)
    for i in range(0, n, batch_size):
        chunk = examples[i : i + batch_size]
        if len(chunk) < batch_size:
            if drop_remainder and out:
                break
            # pad by repeating (masked examples keep statistics unbiased enough
            # for a synthetic corpus; real pipelines would use bucketing)
            chunk = chunk + chunk[: batch_size - len(chunk)]
        tokens = jnp.asarray(np.stack([e.tokens for e in chunk]))
        labels = jnp.asarray(np.stack([e.labels for e in chunk]))
        mask = jnp.asarray(np.stack([e.mask for e in chunk]))
        patches = None
        if chunk[0].image is not None:
            patches = jnp.asarray(np.stack([e.image for e in chunk]))
        out.append(Batch(tokens=tokens, labels=labels, mask=mask, patches=patches))
    return out


def make_federated_data(
    cfg,
    *,
    n_clients: int = 5,
    examples_per_client: int = 64,
    alpha: float = 1.0,
    batch_size: int = 8,
    seq_len: int = 32,
    seed: int = 0,
    task_id: int = 0,
    eval_frac: float = 0.25,
) -> Tuple[Dict[int, List[Batch]], Dict[int, List[Batch]], SyntheticVQA]:
    """Generate + Dirichlet-partition a synthetic VQA corpus for ``cfg``.

    Returns (train_batches, eval_batches, corpus).
    """
    gen = SyntheticVQA(
        vocab_size=cfg.vocab_size,
        seq_len=seq_len,
        frontend_dim=cfg.frontend_dim,
        n_patches=_n_patches(cfg),
        task_id=task_id,
    )
    total = n_clients * examples_per_client
    examples = gen.generate(total, seed=seed)
    shards = dirichlet_partition(
        examples, [e.topic for e in examples], n_clients, alpha, seed=seed,
        min_per_client=max(2 * batch_size, 8),
    )
    train, evald = {}, {}
    for k, items in shards.items():
        n_eval = max(int(len(items) * eval_frac), 1)
        evald[k] = examples_to_batches(items[:n_eval], batch_size)
        train[k] = examples_to_batches(items[n_eval:], batch_size)
    return train, evald, gen


def _n_patches(cfg) -> int:
    from repro.models.vision_stub import num_patches

    if cfg.frontend_dim == 0:
        return 0
    return num_patches(cfg)
