"""Synthetic multimodal VQA corpus with planted topic structure.

Real ScienceQA/IconQA + pretrained encoders are unavailable offline
(DESIGN.md §6.1); instead each example is generated from a latent *topic*:

    topic t  ->  image embedding cluster   μ_t + σ·noise   (frontend stub)
             ->  question template         [Q_START, topic word, fillers, Q_END]
             ->  answer                    a = (t·3 + detail) mod n_answers

``detail`` is a per-example attribute carried by BOTH the image embedding
(second moment direction) and a question token, so the task is genuinely
multimodal: the text stream alone identifies the topic but not the detail
(⇒ 𝒜_T alone is weak, as the paper's Tab. 6 finds for vision-centric VQA),
while the image stream carries the disambiguating signal for 𝒜_I.

Dirichlet partitioning over topics (repro.data.partition) then yields
non-IID client shards with *real* covariate and label shift.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.data.tokenizer import (
    ANS_SEP,
    BOS,
    EOS,
    PAD,
    Q_END,
    Q_START,
    ToyTokenizer,
)


@dataclass
class Example:
    topic: int
    detail: int
    tokens: np.ndarray        # (S,) int32 — BOS q … ANS_SEP answer EOS PAD…
    labels: np.ndarray        # (S,) int32 — next-token targets
    mask: np.ndarray          # (S,) float32 — 1 on answer positions
    image: Optional[np.ndarray] = None  # (M, frontend_dim) stub patch embeddings


@dataclass
class SyntheticVQA:
    """Corpus generator. ``task_id`` shifts all clusters/templates so distinct
    task_ids emulate distinct datasets (Tab. 5 cross-task setup)."""

    vocab_size: int
    seq_len: int = 32
    n_topics: int = 8
    n_answers: int = 16
    n_details: int = 4
    frontend_dim: int = 0     # 0 => text-only arch (no image stream)
    n_patches: int = 64
    noise: float = 0.35
    label_noise: float = 0.02
    task_id: int = 0

    def __post_init__(self):
        self.tok = ToyTokenizer(self.vocab_size, self.n_topics, self.n_answers)
        rng = np.random.RandomState(1234 + 17 * self.task_id)
        if self.frontend_dim:
            self.topic_mu = rng.randn(self.n_topics, self.frontend_dim).astype(np.float32)
            self.detail_dir = rng.randn(self.n_details, self.frontend_dim).astype(np.float32)

    def answer_of(self, topic: int, detail: int) -> int:
        return (topic * 3 + detail + 5 * self.task_id) % self.n_answers

    def gen_example(self, rng: np.random.RandomState, topic: int) -> Example:
        detail = rng.randint(self.n_details)
        ans = self.answer_of(topic, detail)
        if self.label_noise > 0 and rng.rand() < self.label_noise:
            ans = rng.randint(self.n_answers)

        q_len = rng.randint(4, max(5, self.seq_len - 8))
        fillers = [self.tok.filler_token(rng.randint(1 << 30)) for _ in range(q_len - 2)]
        q = [Q_START, self.tok.topic_token(topic)] + fillers + [Q_END]
        if self.frontend_dim == 0:
            # text-only: the detail must be textual or the task is unlearnable
            q.insert(2, self.tok.filler_token(1000003 + detail))

        seq = [BOS] + q + [ANS_SEP, self.tok.answer_token(ans), EOS]
        seq = seq[: self.seq_len]
        pad = self.seq_len - len(seq)
        tokens = np.array(seq + [PAD] * pad, np.int32)

        labels = np.concatenate([tokens[1:], [PAD]]).astype(np.int32)
        mask = np.zeros(self.seq_len, np.float32)
        # supervise the answer token (predicted from the ANS_SEP position)
        ans_pos = len(seq) - 3  # index of ANS_SEP in `tokens`
        if 0 <= ans_pos < self.seq_len:
            mask[ans_pos] = 1.0

        image = None
        if self.frontend_dim:
            base = self.topic_mu[topic] + 0.8 * self.detail_dir[detail]
            patches = base[None, :] + self.noise * rng.randn(
                self.n_patches, self.frontend_dim
            ).astype(np.float32)
            image = patches.astype(np.float32)
        return Example(topic=topic, detail=detail, tokens=tokens, labels=labels, mask=mask, image=image)

    def generate(self, n: int, topics: Optional[List[int]] = None, seed: int = 0) -> List[Example]:
        rng = np.random.RandomState(seed + 31 * self.task_id)
        out = []
        for i in range(n):
            t = topics[i % len(topics)] if topics else rng.randint(self.n_topics)
            out.append(self.gen_example(rng, t))
        return out
