from repro.data.partition import dirichlet_partition, partition_stats
from repro.data.pipeline import examples_to_batches, make_federated_data
from repro.data.synthetic import Example, SyntheticVQA
from repro.data.tokenizer import ToyTokenizer

__all__ = [
    "dirichlet_partition",
    "partition_stats",
    "examples_to_batches",
    "make_federated_data",
    "Example",
    "SyntheticVQA",
    "ToyTokenizer",
]
