"""Multi-tenant serving: grouped-LoRA adapter bank, paged KV slots,
continuous batching, adapter hot-swap from federated checkpoints."""
from repro.serving.adapter_bank import (
    AdapterBank,
    AdapterCache,
    AdapterCacheMiss,
    checkpoint_adapter_loader,
    grouped_adapter_apply,
)
from repro.serving.engine import Completion, Request, ServingEngine, generate_naive
from repro.serving.kv_cache import KVSlotManager

__all__ = [
    "AdapterBank",
    "AdapterCache",
    "AdapterCacheMiss",
    "checkpoint_adapter_loader",
    "grouped_adapter_apply",
    "Completion",
    "Request",
    "ServingEngine",
    "generate_naive",
    "KVSlotManager",
]
