"""Slot-paged decode-state pool for the serving engine.

The engine owns ONE fixed-shape decode state for ``n_slots`` concurrent
requests (the page pool) — for attention archs that is the stacked KV cache
(L, n_slots, C, n_kv, hd); for SSM/RG-LRU archs the recurrent states; for
enc-dec both self- and cross-KV. A request occupies exactly one page (slot)
from admission to completion; prefill writes a freshly computed single-
request state into its page, finishing frees the page for the next request
in the queue. Because the pool's shape never changes, the jitted decode step
is compiled once and mixed-length, mixed-tenant traffic never recompiles.

Per-slot decode positions are tracked host-side: attention validity inside
``decode_attention`` derives from the position (slot j valid iff j <= pos),
so a freed page needs no scrubbing — its stale KV is unreachable until a new
prefill overwrites the page wholesale.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import numpy as np

from repro.models import model as model_lib
from repro.utils import tree_bytes


@jax.jit
def _write_page(pool, page, slot):
    """Overwrite pool slot (batch axis 1 of every leaf) with a B=1 state."""
    return jax.tree.map(
        lambda p, s: jax.lax.dynamic_update_index_in_dim(
            p, s[:, 0].astype(p.dtype), slot, axis=1),
        pool, page)


class KVSlotManager:
    """Fixed pool of decode pages over the model's stacked decode state."""

    def __init__(self, cfg, n_slots: int, capacity: int, dtype):
        self.cfg = cfg
        self.n_slots = n_slots
        self.capacity = capacity
        self.state = model_lib.init_state(cfg, n_slots, capacity, dtype)
        self._free: List[int] = list(range(n_slots))
        self.pos = np.zeros((n_slots,), np.int32)  # next decode position

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> Optional[int]:
        """Claim a free page; None when the pool is saturated."""
        if not self._free:
            return None
        return self._free.pop(0)

    def free(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self._free.append(slot)
        self._free.sort()  # deterministic reuse order
        self.pos[slot] = 0

    def write(self, slot: int, page, start_pos: int) -> None:
        """Install a single-request prefill state into ``slot``."""
        self.state = _write_page(self.state, page, slot)
        self.pos[slot] = start_pos

    def page_bytes(self) -> int:
        """Bytes of one page — what admitting a request actually costs."""
        return tree_bytes(self.state) // self.n_slots

    def pool_bytes(self) -> int:
        return tree_bytes(self.state)
