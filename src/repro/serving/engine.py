"""Multi-tenant continuous-batching serving engine.

One frozen backbone, many tenants' NanoAdapters — the deployment half of
FedNano. The engine composes three pieces:

  * :class:`~repro.serving.adapter_bank.AdapterBank` + ``AdapterCache`` —
    per-tenant adapters hot-swapped from federated checkpoints into stacked
    bank arrays; the decode step selects them per row (grouped LoRA).
  * :class:`~repro.serving.kv_cache.KVSlotManager` — a fixed pool of decode
    pages; admission = prefill into a free page, completion frees it.
  * a continuous-batching loop: every engine step first admits queued
    requests into free pages, then runs ONE fixed-shape jitted decode step
    over all pages (per-slot positions via vmap), so mixed-tenant,
    mixed-length traffic never recompiles and never waits for the slowest
    request of a static batch.

Exactness: prompts are right-padded to ``prefill_len``. Under a causal mask
pad rows never influence real rows, and pad KV written at slots
``[L_real, prefill_len)`` is only ever attended AFTER decode has overwritten
it (decode at position p writes slot p before attending slots <= p), so the
padded prefill + batched decode is token-identical to the one-request-at-a-
time path — pinned by tests/test_serving.py. For ring-buffer (sliding-
window) archs the same argument needs the padded prefill to fit the ring,
which __init__ asserts. Recurrent-state families (ssm / hybrid) integrate
every prefill step into their terminal state, so the engine passes the true
prompt length down to ``model.prefill`` — recurrent sub-layers gate pad
steps to an exact identity (dt=0 for SSM, (a,b)=(1,0) for RG-LRU) and slice
their conv windows at the valid length.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapters as nano
from repro.core.types import Batch
from repro.models import model as model_lib
from repro.serving.adapter_bank import (
    AdapterBank,
    AdapterCache,
    grouped_adapter_apply,
)
from repro.serving.kv_cache import KVSlotManager


@dataclass
class Request:
    """One generation request: a tenant id (None = base model, no adapter),
    an unpadded prompt, optionally a modality stream, and a token budget."""

    rid: int
    tenant: Optional[str]
    prompt: np.ndarray                    # (L,) int32, L <= prefill_len
    patches: Optional[np.ndarray] = None  # (M, frontend_dim) f32
    max_new_tokens: int = 8


@dataclass
class Completion:
    rid: int
    tenant: Optional[str]
    tokens: List[int] = field(default_factory=list)


def _min_window(cfg) -> Optional[int]:
    ws = []
    if cfg.sliding_window is not None:
        ws.append(cfg.sliding_window)
    if cfg.family == "hybrid" and cfg.rglru is not None:
        ws.append(cfg.rglru.local_window)
    return min(ws) if ws else None


class ServingEngine:
    def __init__(self, cfg, backbone, *, max_slots: int = 8,
                 prefill_len: int = 32, max_new_tokens: int = 32,
                 n_patches: Optional[int] = None, adapter_slots: int = 8,
                 adapter_loader=None, stop_token: Optional[int] = None,
                 use_pallas_grouped: bool = False):
        from repro.models.vision_stub import num_patches

        self.cfg = cfg
        self.backbone = backbone
        self.max_slots = max_slots
        self.prefill_len = prefill_len
        self.stop_token = stop_token
        self.use_pallas_grouped = use_pallas_grouped

        if cfg.frontend_dim:
            self.n_patches = n_patches if n_patches else num_patches(cfg)
        else:
            self.n_patches = 0
        # image tokens prepend to the decoder stream (vlm); the audio enc
        # stream runs through cross-attention and occupies no decoder slots
        self.img_prefix = (
            self.n_patches if (cfg.frontend_dim and cfg.family != "audio") else 0
        )
        self.capacity = self.img_prefix + prefill_len + max_new_tokens + 1
        w = _min_window(cfg)
        if w is not None and self.img_prefix + prefill_len > w:
            raise ValueError(
                f"padded prefill ({self.img_prefix + prefill_len}) exceeds the "
                f"attention window ({w}): pad slots would evict live KV from "
                "the ring — lower prefill_len or serve a longer-window config")

        self.bank = AdapterBank(cfg, adapter_slots)
        self.cache = AdapterCache(self.bank, loader=adapter_loader)
        self.slots = KVSlotManager(cfg, max_slots, self.capacity,
                                   model_lib.param_dtype(cfg))

        self._aslot = np.full((max_slots,), -1, np.int32)   # bank slot per page
        self._last_tok = np.zeros((max_slots,), np.int32)
        self._active: Dict[int, Completion] = {}
        self._budget: Dict[int, int] = {}
        self._queue: "deque[Request]" = deque()
        self.stats = {"decode_steps": 0, "prefills": 0, "occupancy_sum": 0}

        capacity = self.capacity

        def _gather_adapters(bank_data, aslot):
            """Per-request adapter set from the bank (-1 => exact identity)."""
            live = (aslot >= 0).astype(list(bank_data.values())[0]["up"].dtype)
            safe = jnp.clip(aslot, 0, None)
            return {
                mod: {"down": d["down"][safe], "up": d["up"][safe] * live}
                for mod, d in bank_data.items()
            }

        @jax.jit
        def _prefill(backbone_, bank_data, aslot, tokens, patches, last_idx):
            adapters = _gather_adapters(bank_data, aslot)
            batch = Batch(
                tokens=tokens,
                labels=jnp.zeros_like(tokens),
                mask=jnp.zeros(tokens.shape, jnp.float32),
                patches=patches,
            )
            embeds, positions, _, _, enc = nano.nanoedge_forward(
                cfg, backbone_, adapters, batch)
            state, hidden = model_lib.prefill(
                cfg, backbone_, embeds, positions, capacity, enc_embeds=enc,
                length=last_idx + 1)
            last_h = jax.lax.dynamic_slice_in_dim(hidden, last_idx, 1, axis=1)
            lg = model_lib.logits(cfg, backbone_, last_h)
            return state, jnp.argmax(lg[0, 0], axis=-1).astype(jnp.int32)

        def _apply_text_bank(bank_data, emb, aslots):
            if "text" not in bank_data:
                return emb
            bank = self.bank  # shapes/scale only; arrays come from bank_data
            down, up = bank_data["text"]["down"], bank_data["text"]["up"]
            if self.use_pallas_grouped:
                from repro.kernels.lora import ops as lora_ops

                flat = lora_ops.grouped_lora_residual(
                    emb[:, 0, :], down, up, aslots, scale=bank.scale,
                    interpret=True)
            else:
                from repro.kernels.lora import ref as lora_ref

                flat = lora_ref.grouped_lora_residual(
                    emb[:, 0, :], down, up, aslots, scale=bank.scale)
            return flat[:, None, :]

        @jax.jit
        def _decode(backbone_, bank_data, pool, toks, pos, aslots):
            # ONE jitted step: embed -> grouped per-tenant adapter -> decode.
            emb = model_lib.embed_tokens(cfg, backbone_, toks[:, None])
            emb = _apply_text_bank(bank_data, emb, aslots)

            def one(page, e, p):
                # vmap maps over the pool's batch axis (1); decode_step wants
                # an explicit B=1 state, so re-insert/strip that axis here
                page = jax.tree.map(lambda a: jnp.expand_dims(a, 1), page)
                lg, page2 = model_lib.decode_step(
                    cfg, backbone_, e[None, None], page, p)
                page2 = jax.tree.map(lambda a: jnp.squeeze(a, 1), page2)
                return lg[0], page2

            lg, pool2 = jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
                pool, emb[:, 0, :], pos)
            nxt = jnp.argmax(lg[:, 0, :], axis=-1).astype(jnp.int32)
            return nxt, pool2

        self._prefill_fn = _prefill
        self._decode_fn = _decode

    # -- queue interface ----------------------------------------------------

    def submit(self, request: Request) -> None:
        if len(request.prompt) > self.prefill_len:
            raise ValueError(
                f"prompt of {len(request.prompt)} exceeds prefill_len="
                f"{self.prefill_len}")
        self._queue.append(request)

    def run(self, requests: Optional[List[Request]] = None) -> Dict[int, Completion]:
        """Drain the queue; returns {rid: Completion} in submission order."""
        for r in requests or []:
            self.submit(r)
        done: Dict[int, Completion] = {}
        while self._queue or self._active:
            self._admit(done)
            self._step(done)
        return done

    # -- internals ----------------------------------------------------------

    def _admit(self, done: Dict[int, Completion]) -> None:
        while self._queue and self.slots.n_free > 0:
            r = self._queue.popleft()
            aslot = self.cache.acquire(r.tenant)
            prompt = np.asarray(r.prompt, np.int32)
            L = len(prompt)
            tokens = np.zeros((1, self.prefill_len), np.int32)
            tokens[0, :L] = prompt
            patches = None
            if r.patches is not None:
                patches = jnp.asarray(r.patches, jnp.float32)[None]
            last_idx = self.img_prefix + L - 1
            page, tok0 = self._prefill_fn(
                self.backbone, self.bank.data, jnp.int32(aslot),
                jnp.asarray(tokens), patches, jnp.int32(last_idx))
            self.stats["prefills"] += 1
            tok0 = int(tok0)
            comp = Completion(rid=r.rid, tenant=r.tenant, tokens=[tok0])
            if r.max_new_tokens <= 1 or tok0 == self.stop_token:
                self.cache.release(r.tenant)
                done[r.rid] = comp
                continue
            slot = self.slots.alloc()
            self.slots.write(slot, page, start_pos=last_idx + 1)
            self._aslot[slot] = aslot
            self._last_tok[slot] = tok0
            self._active[slot] = comp
            self._budget[slot] = r.max_new_tokens - 1

    def _step(self, done: Dict[int, Completion]) -> None:
        if not self._active:
            return
        nxt, pool = self._decode_fn(
            self.backbone, self.bank.data, self.slots.state,
            jnp.asarray(self._last_tok), jnp.asarray(self.slots.pos),
            jnp.asarray(self._aslot))
        self.slots.state = pool
        nxt = np.asarray(nxt)
        self.stats["decode_steps"] += 1
        self.stats["occupancy_sum"] += len(self._active)
        for slot in sorted(self._active):
            comp = self._active[slot]
            tok = int(nxt[slot])
            comp.tokens.append(tok)
            self.slots.pos[slot] += 1
            self._last_tok[slot] = tok
            self._budget[slot] -= 1
            if self._budget[slot] <= 0 or tok == self.stop_token:
                self.cache.release(comp.tenant)
                self.slots.free(slot)
                self._aslot[slot] = -1
                del self._active[slot]
                del self._budget[slot]
                done[comp.rid] = comp

    def mean_occupancy(self) -> float:
        s = self.stats
        return s["occupancy_sum"] / max(1, s["decode_steps"])


# ---------------------------------------------------------------------------
# naive per-tenant loop — the pre-engine serving path, kept as the baseline
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _naive_steps(cfg):
    """The OLD launch/serve.py shape: jitted prefill + jitted decode with the
    per-token text-adapter apply in un-jitted host Python between them."""

    @functools.partial(jax.jit, static_argnames=("capacity",))
    def prefill(backbone, embeds, positions, enc, *, capacity):
        state, hidden = model_lib.prefill(cfg, backbone, embeds, positions,
                                          capacity, enc_embeds=enc)
        return state, model_lib.logits(cfg, backbone, hidden[:, -1:, :])

    @jax.jit
    def decode(backbone, state, emb, pos):
        return model_lib.decode_step(cfg, backbone, emb, state, pos)

    return prefill, decode


def generate_naive(cfg, backbone, requests: List[Request],
                   adapters_by_tenant: Optional[Dict[str, Dict]] = None,
                   *, stop_token: Optional[int] = None) -> Dict[int, Completion]:
    """Serve requests one at a time with one adapter set resident at a time.

    Unpadded prompts (every new length recompiles prefill), host-Python
    adapter math inside the decode loop, no cross-request batching: exactly
    the path the engine replaces, and the reference it must match token-for-
    token (tests/test_serving.py) and beat on throughput (serve_bench).
    """
    adapters_by_tenant = adapters_by_tenant or {}
    identity = nano.init_nanoedge(jax.random.PRNGKey(0), cfg)
    identity = jax.tree.map(jnp.zeros_like, identity)
    prefill, decode = _naive_steps(cfg)
    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha)
    done: Dict[int, Completion] = {}
    for r in requests:
        adapters = adapters_by_tenant.get(r.tenant, identity)
        prompt = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
        patches = None
        if r.patches is not None:
            patches = jnp.asarray(r.patches, jnp.float32)[None]
        batch = Batch(tokens=prompt, labels=jnp.zeros_like(prompt),
                      mask=jnp.zeros(prompt.shape, jnp.float32), patches=patches)
        embeds, positions, _, _, enc = nano.nanoedge_forward(
            cfg, backbone, adapters, batch)
        capacity = embeds.shape[1] + r.max_new_tokens + 1
        state, last = prefill(backbone, embeds, positions, enc,
                              capacity=capacity)
        tok = jnp.argmax(last[:, 0], axis=-1).astype(jnp.int32)
        comp = Completion(rid=r.rid, tenant=r.tenant, tokens=[int(tok[0])])
        for step in range(r.max_new_tokens - 1):
            if comp.tokens[-1] == stop_token:
                break
            pos = jnp.int32(embeds.shape[1] + step)
            emb = model_lib.embed_tokens(cfg, backbone, tok[:, None])
            if "text" in adapters:
                emb = nano.nano_adapter_apply(adapters["text"], emb, **kw)
            lg, state = decode(backbone, state, emb, pos)
            tok = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
            comp.tokens.append(int(tok[0]))
        done[r.rid] = comp
    return done
