"""Stacked NanoAdapter bank + LRU tenant cache (the hot-swap layer).

FedNano's deployment story is one frozen backbone shared by many per-client
NanoAdapter sets. The serving engine realizes that with a *bank*: for each
modality the per-tenant ``down``/``up`` matrices are stacked into
(N_slots, D, r) / (N_slots, r, D) arrays that the grouped LoRA kernel (and
its jnp reference) index per row. Tenants map to bank slots through an LRU
:class:`AdapterCache` that loads adapter sets from federated checkpoints on
miss and overwrites the evicted slot in place — the backbone is never
touched, so a swap moves ~2·D·r floats per modality, not a model.

Slot index -1 is the implicit identity adapter (no tenant): the grouped
kernel passes those rows through untouched.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, Optional

import jax.numpy as jnp


class AdapterBank:
    """Per-modality stacked adapter arrays, indexed by bank slot."""

    def __init__(self, cfg, n_slots: int):
        if n_slots < 1:
            raise ValueError("adapter bank needs at least one slot")
        acfg = cfg.adapter
        dtype = jnp.dtype(acfg.dtype)
        self.cfg = cfg
        self.n_slots = n_slots
        self.rank = acfg.rank
        self.alpha = acfg.alpha
        self.modalities = tuple(acfg.modalities)
        # zero down AND zero up: unwritten slots are exact identity adapters
        self.data = {
            mod: {
                "down": jnp.zeros((n_slots, cfg.d_model, acfg.rank), dtype),
                "up": jnp.zeros((n_slots, acfg.rank, cfg.d_model), dtype),
            }
            for mod in self.modalities
        }

    @property
    def scale(self) -> float:
        return self.alpha / self.rank

    def set_slot(self, slot: int, adapters: Dict) -> None:
        """Hot-swap one tenant's NanoAdapter set into ``slot``."""
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} outside bank of {self.n_slots}")
        for mod in self.modalities:
            if mod not in adapters:
                raise KeyError(f"adapter set missing modality {mod!r}")
            for name in ("down", "up"):
                ref = self.data[mod][name]
                leaf = jnp.asarray(adapters[mod][name], ref.dtype)
                if leaf.shape != ref.shape[1:]:
                    raise ValueError(
                        f"{mod}/{name} shape {leaf.shape} != bank slot shape "
                        f"{ref.shape[1:]}")
                self.data[mod][name] = ref.at[slot].set(leaf)

    def banks(self, mod: str):
        """(down (N, D, r), up (N, r, D)) for one modality."""
        d = self.data[mod]
        return d["down"], d["up"]


def grouped_adapter_apply(bank: AdapterBank, mod: str, x, idx, *,
                          use_pallas: bool = False):
    """Apply per-row tenant adapters from the bank: x (..., D), idx (...)."""
    down, up = bank.banks(mod)
    if use_pallas:
        from repro.kernels.lora import ops as lora_ops

        return lora_ops.grouped_lora_residual(
            x, down, up, idx, scale=bank.scale, interpret=True)
    from repro.kernels.lora import ref as lora_ref

    return lora_ref.grouped_lora_residual(x, down, up, idx, scale=bank.scale)


class AdapterCacheMiss(KeyError):
    """A tenant's adapters are neither cached nor loadable."""


class AdapterCache:
    """LRU tenant→slot map over an :class:`AdapterBank`.

    ``acquire`` pins a tenant's slot for the lifetime of its in-flight
    requests (a pinned slot is never evicted — overwriting adapters under a
    decoding request would corrupt its stream); ``release`` unpins. Misses
    call ``loader(tenant_id)`` — typically a federated-checkpoint reader
    (:func:`checkpoint_adapter_loader`) — and install into the LRU victim.
    """

    def __init__(self, bank: AdapterBank,
                 loader: Optional[Callable[[str], Dict]] = None):
        self.bank = bank
        self.loader = loader
        self._lru: "OrderedDict[str, int]" = OrderedDict()  # tenant -> slot
        self._pins: Dict[str, int] = {}
        self._free = list(range(bank.n_slots))
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __contains__(self, tenant: Optional[str]) -> bool:
        return tenant in self._lru

    def put(self, tenant: str, adapters: Dict) -> int:
        """Install a tenant's adapters directly (no loader round-trip)."""
        slot = self._slot_for(tenant)
        self.bank.set_slot(slot, adapters)
        return slot

    def acquire(self, tenant: Optional[str]) -> int:
        """Pin ``tenant`` into the bank; returns its slot (-1 = identity)."""
        if tenant is None:
            return -1
        if tenant in self._lru:
            self.hits += 1
            self._lru.move_to_end(tenant)
        else:
            self.misses += 1
            if self.loader is None:
                raise AdapterCacheMiss(
                    f"tenant {tenant!r} not cached and no loader configured")
            adapters = self.loader(tenant)
            self.bank.set_slot(self._slot_for(tenant), adapters)
        self._pins[tenant] = self._pins.get(tenant, 0) + 1
        return self._lru[tenant]

    def release(self, tenant: Optional[str]) -> None:
        if tenant is None:
            return
        n = self._pins.get(tenant, 0)
        if n <= 1:
            self._pins.pop(tenant, None)
        else:
            self._pins[tenant] = n - 1

    def _slot_for(self, tenant: str) -> int:
        """Slot for a (new or existing) tenant, evicting LRU if needed."""
        if tenant in self._lru:
            self._lru.move_to_end(tenant)
            return self._lru[tenant]
        if self._free:
            slot = self._free.pop(0)
        else:
            victim = next(
                (t for t in self._lru if self._pins.get(t, 0) == 0), None)
            if victim is None:
                raise AdapterCacheMiss(
                    "adapter bank thrashing: every slot is pinned by an "
                    "in-flight request — grow adapter_slots past max_slots")
            slot = self._lru.pop(victim)
            self.evictions += 1
        self._lru[tenant] = slot
        return slot

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "resident": len(self._lru)}


def checkpoint_adapter_loader(cfg, root: str) -> Callable[[str], Dict]:
    """Tenant loader over a directory of federated checkpoints.

    ``root/<tenant>`` may be a ``save_server_checkpoint`` directory (v2 —
    the adapters live in ``global_adapters.npz``) or a bare ``.npz`` written
    by ``save_pytree``; either restores strictly against this config's
    NanoAdapter structure.
    """
    import os

    import jax

    from repro.checkpoint import load_adapters
    from repro.core import adapters as nano

    reference = nano.init_nanoedge(jax.random.PRNGKey(0), cfg)

    def load(tenant: str) -> Dict:
        path = os.path.join(root, tenant)
        if not os.path.exists(path) and os.path.exists(path + ".npz"):
            path = path + ".npz"
        return load_adapters(path, reference)

    return load
