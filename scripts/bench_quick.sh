#!/usr/bin/env bash
# Wiring checks for the benchmarks: tiny workloads, no JSON output.
# Part of scripts/smoke.sh; run the full sweeps with
#   PYTHONPATH=src python benchmarks/engine_bench.py
#   PYTHONPATH=src python benchmarks/serve_bench.py
#   PYTHONPATH=src python benchmarks/kernel_bench.py   # appends BENCH_kernels.json
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python benchmarks/engine_bench.py --quick "$@"
python benchmarks/serve_bench.py --quick
python benchmarks/kernel_bench.py --quick
