#!/usr/bin/env bash
# Wiring check for the round-engine benchmark: tiny cohorts, no JSON output.
# Part of scripts/smoke.sh; run the full sweep with
#   PYTHONPATH=src python benchmarks/engine_bench.py
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python benchmarks/engine_bench.py --quick "$@"
