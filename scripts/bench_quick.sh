#!/usr/bin/env bash
# Wiring checks for the benchmarks: tiny workloads, no JSON output.
# Part of scripts/smoke.sh; run the full sweeps with
#   PYTHONPATH=src python benchmarks/engine_bench.py
#   PYTHONPATH=src python benchmarks/serve_bench.py
#   PYTHONPATH=src python benchmarks/kernel_bench.py   # appends BENCH_kernels.json
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python benchmarks/engine_bench.py --quick "$@"
# sharded-engine smoke: forces a 2-device CPU mesh and runs the
# vmap-vs-sharded comparison end to end (fresh process — the topology
# flag must precede jax init, so it can't share the run above)
python benchmarks/engine_bench.py --quick --devices 2
python benchmarks/serve_bench.py --quick
python benchmarks/kernel_bench.py --quick
