"""Regenerate tests/golden/strategy_parity.json.

Runs every registered strategy (plus the DP+int8 upload-path variant) on a
tiny seeded config and records the FederatedResult metrics. The goldens were
first captured on the PRE-plugin string-dispatch implementation, so
tests/test_strategies.py asserting against them proves the registry path is
numerically identical to the legacy path.

    PYTHONPATH=src python scripts/gen_strategy_goldens.py
"""
import json
import os

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data

STRATEGIES = ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft")


def parity_setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=4, examples_per_client=16, alpha=1.0, batch_size=4,
        seq_len=16,
    )
    return cfg, train, evald


def run_one(cfg, train, evald, strategy, hp):
    from repro.utils import tree_sq_norm

    res = run_federated(
        jax.random.PRNGKey(0), cfg, train, evald, strategy=strategy,
        rounds=2, hp=hp,
    )
    fisher0 = res.clients[0].fisher
    return {
        "round_losses": [m["mean_loss"] for m in res.round_metrics],
        "client_accuracy": {str(c): a for c, a in res.client_accuracy.items()},
        "avg_accuracy": res.avg_accuracy,
        "comm_totals": {k: int(v) for k, v in res.comm_totals.items()},
        # pytree checksums: pin the actual parameter trajectories, not just
        # the (possibly degenerate-at-toy-scale) accuracy numbers
        "global_sq_norm": float(tree_sq_norm(res.server.global_adapters)),
        "client0_sq_norm": float(tree_sq_norm(res.clients[0].adapters)),
        "client0_fisher_sq_norm": (
            float(tree_sq_norm(fisher0)) if fisher0 is not None else None
        ),
    }


def main():
    cfg, train, evald = parity_setup()
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    golden = {}
    for s in STRATEGIES:
        golden[s] = run_one(cfg, train, evald, s, hp)
        print(f"  {s}: avg_acc {golden[s]['avg_accuracy']:.6f}")
    hp_wire = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2,
                          dp_clip=1.0, dp_noise=0.01, compress_uploads=True)
    golden["fednano+dp+int8"] = run_one(cfg, train, evald, "fednano", hp_wire)
    print(f"  fednano+dp+int8: avg_acc {golden['fednano+dp+int8']['avg_accuracy']:.6f}")

    out = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                       "strategy_parity.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
