"""Regenerate the committed RunState golden fixture.

    PYTHONPATH=src python scripts/gen_runstate_golden.py

Writes ``tests/golden/run_state/`` — a hand-built, fully deterministic
snapshot (arange-derived arrays, no PRNG, no training) that pins the
on-disk layout of ``repro.checkpoint.run_state``: npz key paths, meta.json
fields, and leaf values. ``tests/test_checkpoint_io.py`` loads it with
today's code; if the format changes, that test fails and the change must be
deliberate (bump RUN_STATE_VERSION and regenerate).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import RunState, save_run_state
from repro.core.client import ClientState
from repro.core.comm import RoundTraffic
from repro.optim import adamw_init
from repro.utils import tree_zeros_like

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "golden",
                   "run_state")


def seq(shape, start):
    n = int(np.prod(shape))
    return (jnp.arange(start, start + n, dtype=jnp.float32) / 8.0).reshape(shape)


def make_adapters(base):
    return {"layer0": {"A": seq((2, 3), base), "B": seq((3, 2), base + 6)}}


def make_client(cid, base, with_fisher):
    adp = make_adapters(base)
    opt = jax.tree.map(lambda x: jnp.full(x.shape, 0.25, x.dtype),
                       adamw_init(adp))
    fisher = (jax.tree.map(lambda x: jnp.ones_like(x), adp)
              if with_fisher else None)
    return ClientState(cid=cid, adapters=adp, opt_state=opt,
                       n_examples=4 + cid, fisher=fisher,
                       rounds_participated=2)


def build():
    return RunState(
        engine="sequential",
        strategy="fedavg",
        round_idx=2,
        server_round_idx=2,
        rng_key=np.asarray(jax.random.PRNGKey(0)),
        global_adapters=make_adapters(100),
        server_opt_state=None,
        clients=[make_client(0, 0, with_fisher=True),
                 make_client(1, 50, with_fisher=False)],
        tstates=[[tree_zeros_like(make_adapters(0))], [None]],
        round_metrics=[
            {"round": 0, "mean_loss": 1.5, "participants": 2},
            {"round": 1, "mean_loss": 1.25, "participants": 2},
        ],
        comm_rounds=[
            RoundTraffic(round_idx=0, param_up=96, param_down=48,
                         param_up_wire=96).to_dict(),
            RoundTraffic(round_idx=1, param_up=96, param_down=48,
                         param_up_wire=32).to_dict(),
        ],
        meta_extra={"cfg_name": "golden-fixture"},
    )


if __name__ == "__main__":
    out = os.path.normpath(OUT)
    save_run_state(out, build())
    data = np.load(os.path.join(out, "run_state.npz"))
    print(f"wrote {out}")
    for k in sorted(data.files):
        print(" ", k, data[k].shape, data[k].dtype)
