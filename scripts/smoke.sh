#!/usr/bin/env bash
# Fast pre-commit signal: the smoke-marked test per module (<2 min) instead
# of the full ~9-minute tier-1 suite. Usage: scripts/smoke.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m smoke "$@"
