#!/usr/bin/env bash
# Fast pre-commit signal: the smoke-marked test per module (<2 min) instead
# of the full ~9-minute tier-1 suite. Usage: scripts/smoke.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q -m smoke "$@"
scripts/bench_quick.sh
