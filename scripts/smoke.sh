#!/usr/bin/env bash
# Fast pre-commit signal: the smoke-marked test per module (<2 min) instead
# of the full ~9-minute tier-1 suite. Usage: scripts/smoke.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q -m smoke "$@"

# checkpoint/resume through the CLI: kill a run at round 2, resume to 3,
# and require the resumed summary to agree with the killed run's history
out=$(mktemp -d)
trap 'rm -rf "$out"' EXIT
common=(--arch llava-1.5-7b --strategy fednano --clients 2 --rounds 2
        --local-steps 1 --examples-per-client 8 --batch-size 2 --seq-len 8)
python -m repro.launch.train "${common[@]}" --out "$out/a" >/dev/null
python -m repro.launch.train "${common[@]}" --rounds 3 \
    --resume "$out/a/state" --out "$out/b" >/dev/null
python - "$out" <<'EOF'
import json, sys
a = json.load(open(sys.argv[1] + "/a/llava-1.5-7b_fednano.json"))
b = json.load(open(sys.argv[1] + "/b/llava-1.5-7b_fednano.json"))
assert len(b["rounds"]) == 3, b["rounds"]
for ra, rb in zip(a["rounds"], b["rounds"]):
    assert abs(ra["mean_loss"] - rb["mean_loss"]) < 1e-6, (ra, rb)
print("resume smoke OK: first rounds replayed within 1e-6")
EOF

# multi-tenant serving: 2 tenants, distinct adapters, engine must match the
# naive one-request-at-a-time loop token-for-token (exits nonzero otherwise)
python -m repro.launch.serve --arch h2o-danube-1.8b --tenants 2 \
    --requests 6 --gen-tokens 4 --prefill-len 8 --slots 2 --naive \
    | tail -2
scripts/bench_quick.sh
