"""End-to-end federated driver: FedNano vs FedAvg vs LocFT on non-IID VQA.

    PYTHONPATH=src python examples/federated_vqa.py [--rounds 5] [--clients 5]

Runs the full Alg.-1 protocol — Dirichlet(α=1) split over a synthetic
multimodal corpus, per-round local NanoAdapter tuning, diagonal-FIM
estimation, Fisher-merged aggregation — and prints the per-client accuracy
table plus the communication ledger. This is the runnable counterpart of
paper Tab. 2 (reduced backbone: 1 CPU core here; the full-scale server step
is proven by the multi-pod dry-run, see DESIGN.md §6.2).
"""
import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data
from repro.strategies import available_strategies, get_strategy
from repro.utils import fmt_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--strategies", default="locft,fedavg,fednano",
                    help=f"comma-separated registry names; registered: "
                         f"{', '.join(available_strategies())}")
    ap.add_argument("--scale", choices=["tiny", "small"], default="tiny",
                    help="small ≈ 25M backbone (slower; a few hundred total steps)")
    args = ap.parse_args()

    dims = dict(tiny=dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, frontend_dim=64, vocab_size=512),
                small=dict(n_layers=4, d_model=320, n_heads=8, n_kv_heads=8,
                           head_dim=40, d_ff=1280, frontend_dim=128, vocab_size=16384))
    cfg = get_smoke_config("llava-1.5-7b").with_(**dims[args.scale])

    train, evald, _ = make_federated_data(
        cfg, n_clients=args.clients, examples_per_client=48, alpha=args.alpha,
        batch_size=8, seq_len=24,
    )
    hp = HyperParams(lr=5e-3, local_steps=args.local_steps, fisher_batches=2)
    total_steps = args.rounds * args.clients * args.local_steps
    print(f"== federated VQA: K={args.clients} R={args.rounds} T={args.local_steps} "
          f"(≈{total_steps} local steps/strategy), α={args.alpha}, scale={args.scale}")

    results = {}
    # resolve every name up front so a typo fails before any training time
    for strategy in [get_strategy(n.strip()) for n in args.strategies.split(",")]:
        t0 = time.time()
        res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                            strategy=strategy, rounds=args.rounds, hp=hp, verbose=True)
        results[strategy.name] = res
        print(f"  -> {strategy.name}: avg acc {100*res.avg_accuracy:.2f}% "
              f"({time.time()-t0:.0f}s)")

    print("\nper-client accuracy (%):")
    cids = sorted(next(iter(results.values())).client_accuracy)
    print("strategy    " + "".join(f"C{c+1:<7}" for c in cids) + "avg")
    for s, res in results.items():
        cells = "".join(f"{100*res.client_accuracy[c]:<8.2f}" for c in cids)
        print(f"{s:<12}{cells}{100*res.avg_accuracy:.2f}")

    ledger_name = "fednano" if "fednano" in results else next(reversed(results))
    ct = results[ledger_name].comm_totals
    print(f"\n{ledger_name} communication ledger over {args.rounds} rounds × {args.clients} clients:")
    print(f"  adapter uploads   {fmt_bytes(ct['param_up'])}")
    print(f"  diag-FIM uploads  {fmt_bytes(ct['fisher_up'])}")
    print(f"  merged broadcast  {fmt_bytes(ct['param_down'])}")


if __name__ == "__main__":
    main()
