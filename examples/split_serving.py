"""Split serving: client-side NanoEdge + server-side frozen backbone decode.

    PYTHONPATH=src python examples/split_serving.py

Serves a batch of VQA requests the FedNano way: the *client* embeds the
question tokens, connects the image patches, and applies its tuned
NanoAdapters; the *server* (which alone holds the LLM) runs prefill and then
greedy decode, returning one token per step. Every tensor that would cross
the wire is byte-accounted, mirroring repro.core.split for inference.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import adapters as nano
from repro.data import SyntheticVQA, examples_to_batches
from repro.models import model as backbone_lib
from repro.strategies import get_strategy
from repro.utils import fmt_bytes, tree_bytes


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, frontend_dim=64,
    )
    backbone = backbone_lib.init_backbone(key, cfg)       # SERVER
    # CLIENT: a tuned FedNano participant (init_client = adapters + opt state)
    adapters = get_strategy("fednano").init_client(
        jax.random.fold_in(key, 1), cfg, cid=0, n_examples=8
    ).adapters

    gen = SyntheticVQA(vocab_size=cfg.vocab_size, seq_len=24,
                       frontend_dim=cfg.frontend_dim, n_patches=8)
    batch = examples_to_batches(gen.generate(8, seed=1), batch_size=8)[0]
    B = batch.tokens.shape[0]

    # ---- CLIENT: NanoEdge forward (the only model code the client runs) ----
    embeds, positions, _, _, _ = nano.nanoedge_forward(cfg, backbone, adapters, batch)
    wire_up = tree_bytes(embeds)

    # ---- SERVER: prefill + batched greedy decode over the frozen LLM ----
    capacity = embeds.shape[1] + 8

    @jax.jit
    def prefill(embeds, positions):
        state, hidden = backbone_lib.prefill(cfg, backbone, embeds, positions, capacity)
        last = backbone_lib.logits(cfg, backbone, hidden[:, -1:, :])
        return state, last

    @jax.jit
    def decode(state, emb, pos):
        return backbone_lib.decode_step(cfg, backbone, emb, state, pos)

    state, last = prefill(embeds, positions)
    tok = jnp.argmax(last[:, 0], axis=-1)
    generated = [tok]
    wire_down = last.nbytes

    kw = dict(rank=cfg.adapter.rank, alpha=cfg.adapter.alpha)
    for step in range(4):
        pos = jnp.int32(embeds.shape[1] + step)
        # client embeds + adapts the freshly sampled token, ships (B,1,D) up
        emb = backbone_lib.embed_tokens(cfg, backbone, tok[:, None])
        emb = nano.nano_adapter_apply(adapters["text"], emb, **kw)
        wire_up += emb.nbytes
        lg, state = decode(state, emb, pos)
        wire_down += lg.nbytes
        tok = jnp.argmax(lg[:, 0], axis=-1)
        generated.append(tok)

    gen_tokens = jnp.stack(generated, axis=1)
    print(f"served batch of {B} requests; generated 5 tokens each:")
    for i in range(B):
        toks = [int(t) for t in gen_tokens[i]]
        answers = [gen.tok.decode_answer(t) if gen.tok.is_answer(t) else None for t in toks]
        print(f"  req {i}: tokens {toks} answers {answers}")
    print(f"wire traffic: client->server {fmt_bytes(wire_up)}, "
          f"server->client {fmt_bytes(int(wire_down))} "
          f"(vs shipping the backbone: {fmt_bytes(tree_bytes(backbone))})")


if __name__ == "__main__":
    main()
