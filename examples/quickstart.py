"""Quickstart: tune NanoAdapters against a frozen backbone in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced LLaVA-style backbone (frozen), attaches NanoEdge
(trainable 𝒜_T + 𝒜_I), and runs a short local tuning loop on synthetic
VQA triplets — the client-side experience of FedNano.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import adapters as nano
from repro.core.types import Batch
from repro.data import SyntheticVQA, examples_to_batches
from repro.models import model as backbone_lib
from repro.optim import adamw_update
from repro.strategies import get_strategy


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, frontend_dim=64,
    )

    # 1. frozen backbone (server-side) + trainable NanoEdge (client-side);
    #    the strategy's init_client hook builds adapters + optimizer state
    backbone = backbone_lib.init_backbone(key, cfg)
    client = get_strategy("fednano").init_client(
        jax.random.fold_in(key, 1), cfg, cid=0, n_examples=64
    )
    adapters, opt_state = client.adapters, client.opt_state

    # 2. synthetic VQA shard
    gen = SyntheticVQA(vocab_size=cfg.vocab_size, seq_len=24,
                       frontend_dim=cfg.frontend_dim, n_patches=8)
    batches = examples_to_batches(gen.generate(64, seed=0), batch_size=8)

    # 3. the FedNano local objective: grads w.r.t. adapters ONLY
    @jax.jit
    def step(adapters, opt_state, batch):
        def loss_fn(adp):
            loss, _ = nano.fednano_loss(cfg, backbone, adp, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(adapters)
        adapters, opt_state = adamw_update(grads, opt_state, adapters, lr=5e-3)
        return adapters, opt_state, loss

    print(f"backbone frozen; trainable adapter params: "
          f"{nano.adapter_param_count(cfg):,}")
    for epoch in range(6):
        losses = []
        for b in batches:
            adapters, opt_state, loss = step(adapters, opt_state, b)
            losses.append(float(loss))
        print(f"epoch {epoch}: loss {sum(losses)/len(losses):.4f}")
    print("done — adapters are the ONLY thing that changed (and the only "
          "thing a FedNano client would upload).")


if __name__ == "__main__":
    main()
