"""System-level behaviour: the paper's efficiency claims + sharding rules.

Tab. 1 claims (LLaVA-1.5-7B, rank-64 adapters):
    server uploads ≈ 1.05M params (0.01% of the model)
    client storage cut ≥ 95% vs full-model PEFT-FL
These are analytic properties of the architecture — reproduced exactly.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config, get_smoke_config
from repro.core.comm import (
    adapter_upload_params,
    backbone_param_count,
    client_storage_params,
)


@pytest.mark.smoke
def test_table1_upload_params_match_paper():
    cfg = get_config("llava-1.5-7b")
    up = adapter_upload_params(cfg)
    # 2 adapters × 2 × 4096 × 64 = 1,048,576 ≈ the paper's 1.05M
    assert up == 2 * 2 * 4096 * 64
    assert abs(up / 1e6 - 1.05) < 0.01


def test_table1_upload_fraction_0p01_percent():
    cfg = get_config("llava-1.5-7b")
    total = backbone_param_count(cfg) + 303_500_000  # + vision tower stub
    frac = adapter_upload_params(cfg) / total
    assert frac < 2e-4, f"upload fraction {frac:.2e} should be ~0.01%"


def test_table1_client_storage_reduction_over_90():
    cfg = get_config("llava-1.5-7b")
    s = client_storage_params(cfg)
    reduction = 1 - s["fednano_client_total"] / s["peft_client_total"]
    assert reduction > 0.90, f"client storage reduction {reduction:.3f}"
    # and the paper's headline ≥95% holds for the 7B backbone
    assert reduction > 0.95


def test_backbone_param_count_close_to_materialized():
    """Analytic count within 2% of the actually-initialized reduced model."""
    from repro.models import model as M
    from repro.utils import tree_size

    for arch in ("h2o-danube-1.8b", "grok-1-314b", "mamba2-130m", "recurrentgemma-9b", "whisper-base"):
        cfg = get_smoke_config(arch)
        params = M.init_backbone(jax.random.PRNGKey(0), cfg)
        got = tree_size(params)
        want = backbone_param_count(cfg)
        assert abs(got - want) / got < 0.02, f"{arch}: analytic {want} vs real {got}"


def test_known_scale_param_counts():
    """Full configs land near their nameplate sizes."""
    approx = {
        "h2o-danube-1.8b": (1.8e9, 0.25),
        "glm4-9b": (9e9, 0.25),
        "grok-1-314b": (314e9, 0.15),
        "mamba2-130m": (130e6, 0.25),
        "internlm2-20b": (20e9, 0.25),
    }
    for arch, (want, tol) in approx.items():
        n = backbone_param_count(get_config(arch))
        assert abs(n - want) / want < tol, f"{arch}: {n/1e9:.2f}B vs {want/1e9:.2f}B"


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_param_specs_follow_rules():
    from repro.launch.sharding_rules import param_logical_spec

    assert param_logical_spec(("layers", "attn", "wq"), (64, 128)) == (None, "model")
    assert param_logical_spec(("layers", "attn", "wo"), (128, 64)) == ("model", None)
    assert param_logical_spec(("layers", "mlp", "w_down"), (128, 64)) == ("model", None)
    assert param_logical_spec(("embed", "table"), (1024, 64)) == ("model", None)
    # grok experts: 8 % 16 != 0 -> 2D weight sharding over (data, model)
    assert param_logical_spec(("layers", "moe", "w_up"), (8, 64, 128)) == (None, "data", "model")
    # llama4 experts: 16 % 16 == 0 -> expert-parallel
    assert param_logical_spec(("layers", "moe", "w_up"), (16, 64, 128)) == ("model", None, None)
    assert param_logical_spec(("layers", "norm1", "scale"), (64,)) == (None,)


def test_constrain_noop_without_mesh():
    from repro.sharding import constrain

    x = jnp.ones((4, 4))
    assert constrain(x, ("data", None)) is x


def test_resolve_spec_divisibility_and_alias():
    import numpy as np

    from repro.sharding import resolve_spec

    # fake 4-device mesh via reshaping the single CPU device is not possible;
    # instead exercise the pure logic through a Mesh over repeated axes sizes 1
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    p = resolve_spec(mesh, (16, 32), (("pod", "data"), "model"))
    # "pod" dropped (absent), "data"/"model" kept (divisible by 1)
    assert p == jax.sharding.PartitionSpec("data", "model")


def test_long500k_eligibility():
    from repro.launch.dryrun import shape_supported

    long = INPUT_SHAPES["long_500k"]
    runs = [a for a in ("h2o-danube-1.8b", "recurrentgemma-9b", "mamba2-130m")
            if shape_supported(get_config(a), long)[0]]
    skips = [a for a in ("qwen1.5-4b", "glm4-9b", "grok-1-314b", "whisper-base",
                         "qwen2-vl-72b", "internlm2-20b", "llama4-scout-17b-a16e")
             if not shape_supported(get_config(a), long)[0]]
    assert len(runs) == 3
    assert len(skips) == 7
