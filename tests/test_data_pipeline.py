"""Data pipeline: Dirichlet partition invariants (hypothesis) + corpus checks."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.configs import get_smoke_config
from repro.data import SyntheticVQA, dirichlet_partition, make_federated_data, partition_stats


@settings(max_examples=20, deadline=None)
@given(
    n_items=st.integers(20, 200),
    n_clients=st.integers(2, 8),
    alpha=st.floats(0.05, 10.0),
    seed=st.integers(0, 1000),
)
def test_partition_covers_and_disjoint(n_items, n_clients, alpha, seed):
    rng = np.random.RandomState(seed)
    items = list(range(n_items))
    topics = rng.randint(0, 6, size=n_items)
    shards = dirichlet_partition(items, topics, n_clients, alpha, seed=seed, min_per_client=1)
    got = sorted(x for shard in shards.values() for x in shard)
    assert got == items, "partition must be a disjoint cover"
    assert set(shards) == set(range(n_clients))
    assert all(len(s) >= 1 for s in shards.values())


@pytest.mark.smoke
def test_small_alpha_more_skewed():
    """Dirichlet concentration: smaller α ⇒ more per-client topic skew."""
    rng = np.random.RandomState(0)
    items = list(range(4000))
    topics = rng.randint(0, 8, size=4000)

    def skew(alpha):
        shards = dirichlet_partition(items, topics, 5, alpha, seed=1)
        stats = partition_stats(shards, lambda i: topics[i])
        # mean over clients of (max topic share)
        vals = []
        for hist in stats.values():
            tot = sum(hist.values())
            vals.append(max(hist.values()) / tot if tot else 0)
        return float(np.mean(vals))

    assert skew(0.1) > skew(5.0) + 0.05, (skew(0.1), skew(5.0))


@pytest.mark.smoke
def test_synthetic_corpus_structure():
    gen = SyntheticVQA(vocab_size=512, seq_len=24, frontend_dim=32, n_patches=8)
    ex = gen.generate(50, seed=3)
    assert len(ex) == 50
    for e in ex[:10]:
        assert e.tokens.shape == (24,)
        assert e.labels.shape == (24,)
        assert float(e.mask.sum()) == 1.0  # exactly the answer position
        ans_pos = int(np.argmax(e.mask))
        # label at the supervised position is the answer token
        assert gen.tok.is_answer(int(e.labels[ans_pos]))
        assert e.image.shape == (8, 32)


def test_answer_depends_on_topic_and_detail():
    gen = SyntheticVQA(vocab_size=512)
    a00, a01 = gen.answer_of(0, 0), gen.answer_of(0, 1)
    a10 = gen.answer_of(1, 0)
    assert a00 != a01 or a00 != a10  # non-degenerate mapping


def test_cross_task_ids_shift_distribution():
    g0 = SyntheticVQA(vocab_size=512, task_id=0)
    g1 = SyntheticVQA(vocab_size=512, task_id=1)
    assert g0.answer_of(0, 0) != g1.answer_of(0, 0)


def test_make_federated_data_batches(rng):
    cfg = get_smoke_config("llava-1.5-7b")
    train, evald, gen = make_federated_data(
        cfg, n_clients=3, examples_per_client=24, alpha=1.0, batch_size=4, seq_len=20
    )
    assert set(train) == {0, 1, 2}
    for cid in train:
        assert len(train[cid]) >= 1
        b = train[cid][0]
        assert b.tokens.shape == (4, 20)
        assert b.patches is not None and b.patches.shape[2] == cfg.frontend_dim
