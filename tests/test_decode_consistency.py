"""Prefill + decode_step must reproduce the teacher-forced forward pass.

Covers every sequence-mixing mechanism: SWA ring buffer (danube), GQA cache,
SSM state (mamba2), RG-LRU + local attention (recurrentgemma), enc-dec cross
attention (whisper), M-RoPE (qwen2-vl).
"""
import dataclasses
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models import vision_stub

ARCHS = [
    "h2o-danube-1.8b",
    "qwen1.5-4b",
    "recurrentgemma-9b",
    "qwen2-vl-72b",
    "mamba2-130m",
    "glm4-9b",
    "whisper-base",
    "internlm2-20b",
]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    cfg = get_smoke_config(arch)
    params = M.init_backbone(rng, cfg)
    B, S = 2, 16
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    emb = M.embed_tokens(cfg, params, toks)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc = None
    if cfg.family == "audio":
        feats = vision_stub.patch_embeddings(rng, cfg, B)
        enc = M.connect(cfg, params, feats)
    hidden, _ = M.forward(cfg, params, emb, pos, enc)
    want = M.logits(cfg, params, hidden)

    # prefill on the first half, then decode the second half token by token
    half = S // 2
    state, _ = M.prefill(cfg, params, emb[:, :half], pos[:, :half], capacity=S, enc_embeds=enc)
    for t in range(half, S):
        got, state = M.decode_step(cfg, params, emb[:, t : t + 1], state, jnp.int32(t))
        err = float(jnp.max(jnp.abs(got[:, 0] - want[:, t])))
        assert err < 5e-4, f"{arch}: step {t} logits diverge by {err}"


@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-scout-17b-a16e"])
def test_moe_decode_matches_forward_without_drops(arch, rng):
    """MoE needs capacity slack: with cf large enough (no token drops) the
    decode path must agree with teacher forcing exactly."""
    cfg = get_smoke_config(arch)
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_backbone(rng, cfg)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    emb = M.embed_tokens(cfg, params, toks)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    hidden, _ = M.forward(cfg, params, emb, pos)
    want = M.logits(cfg, params, hidden)
    state, _ = M.prefill(cfg, params, emb[:, : S - 1], pos[:, : S - 1], capacity=S)
    got, _ = M.decode_step(cfg, params, emb[:, S - 1 : S], state, jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(got[:, 0] - want[:, -1])))
    assert err < 5e-4, err


def test_swa_ring_buffer_long_decode(rng):
    """Decode far past the window: ring cache must equal full-cache attention."""
    cfg = get_smoke_config("h2o-danube-1.8b")  # window 64 in smoke
    w = cfg.sliding_window
    params = M.init_backbone(rng, cfg)
    B, S = 1, 2 * w + 8  # well past one wrap
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    emb = M.embed_tokens(cfg, params, toks)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    hidden, _ = M.forward(cfg, params, emb, pos)
    want = M.logits(cfg, params, hidden)

    half = w + 4  # prefill longer than the window: seeds must roll correctly
    state, _ = M.prefill(cfg, params, emb[:, :half], pos[:, :half], capacity=S)
    for t in range(half, S):
        got, state = M.decode_step(cfg, params, emb[:, t : t + 1], state, jnp.int32(t))
        err = float(jnp.max(jnp.abs(got[:, 0] - want[:, t])))
        assert err < 5e-4, f"ring decode diverges at t={t}: {err}"


def test_mrope_distinct_positions(rng):
    """M-RoPE with distinct (t, h, w) components must differ from plain RoPE
    and preserve shapes (exercises the section plumbing)."""
    from repro.models.rotary import make_angles

    cfg = get_smoke_config("qwen2-vl-72b")
    B, S = 2, 8
    text_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    a_text = make_angles(cfg, text_pos)
    pos3 = jnp.stack([text_pos, text_pos * 2, text_pos * 3])
    a_img = make_angles(cfg, pos3)
    assert a_text.shape == a_img.shape
    assert float(jnp.max(jnp.abs(a_text - a_img))) > 1e-3
