"""The sharded round engine (`engine="sharded"`) and its satellites.

Two layers of coverage:

* In-process tests on a 1-device mesh — the mesh/shard_map/padding/pipeline
  machinery all runs (a 1-device mesh is a degenerate but complete mesh),
  so parity here is bitwise and fast. This is where the padding-inertness,
  batched-init, resume, and validation cases live.
* One subprocess test that forces an 8-device CPU topology via XLA_FLAGS
  (must be set before jax initializes, so it can't run in this process —
  tests/conftest.py pins the real 1-CPU topology) and checks all six paper
  strategies against the committed golden, an uneven K=5 cohort, and
  checkpoint/resume. See tests/_sharded_subproc.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.core import client as client_lib
from repro.core.aggregation import _norm_weights, fedavg
from repro.data import make_federated_data
from repro.sharding import CLIENT_AXIS, client_mesh, pad_to_multiple
from repro.strategies.base import Strategy, get_strategy
from repro.utils import tree_sq_norm

HERE = os.path.dirname(os.path.abspath(__file__))


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=4, examples_per_client=16, alpha=1.0, batch_size=4,
        seq_len=16,
    )
    return cfg, train, evald


def _run(cfg, train, evald, strategy, *, rounds=2, **kw):
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    return run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                         strategy=strategy, rounds=rounds, hp=hp, **kw)


def _tree_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def test_client_mesh_shape():
    mesh = client_mesh()
    assert mesh.axis_names == (CLIENT_AXIS,)
    assert mesh.size == jax.device_count()


def test_client_mesh_too_many_devices():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        client_mesh(jax.device_count() + 1)


def test_pad_to_multiple():
    assert pad_to_multiple(5, 8) == 8
    assert pad_to_multiple(8, 8) == 8
    assert pad_to_multiple(9, 8) == 16
    assert pad_to_multiple(0, 8) == 0
    with pytest.raises(ValueError):
        pad_to_multiple(3, 0)


# ---------------------------------------------------------------------------
# sharded engine, 1-device mesh: bitwise parity with vmap
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_sharded_matches_vmap_one_device():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=2, examples_per_client=4, alpha=1.0, batch_size=2,
        seq_len=8,
    )
    hp = HyperParams(lr=5e-3, local_steps=1, fisher_batches=1)
    a = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                      strategy="fednano", rounds=2, hp=hp, engine="vmap")
    b = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                      strategy="fednano", rounds=2, hp=hp, engine="sharded")
    # on a 1-device mesh the shard_map body IS the vmap body, so compute is
    # bitwise identical; the device-side stacked aggregation reorders the
    # f32 merge sums (tensordot over the client axis vs per-client folds),
    # so everything downstream of the first merge agrees to float
    # tolerance, not bitwise
    np.testing.assert_allclose(
        [m["mean_loss"] for m in a.round_metrics],
        [m["mean_loss"] for m in b.round_metrics], rtol=1e-6)
    assert a.comm_totals == b.comm_totals
    np.testing.assert_allclose(a.avg_accuracy, b.avg_accuracy, rtol=1e-6)
    for x, y in zip(jax.tree.leaves(a.server.global_adapters),
                    jax.tree.leaves(b.server.global_adapters)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


def test_sharded_overlap_off_identical(setup):
    cfg, train, evald = setup
    a = _run(cfg, train, evald, "fednano", engine="sharded", overlap=True)
    b = _run(cfg, train, evald, "fednano", engine="sharded", overlap=False)
    # the double buffer changes only WHEN results are collected, never what
    # is computed or the order offers reach aggregation
    assert [m["mean_loss"] for m in a.round_metrics] == \
           [m["mean_loss"] for m in b.round_metrics]
    assert a.comm_totals == b.comm_totals
    assert _tree_equal(a.server.global_adapters, b.server.global_adapters)


def test_devices_arg_rejected_on_other_engines(setup):
    cfg, train, evald = setup
    with pytest.raises(ValueError, match="devices"):
        _run(cfg, train, evald, "fednano", engine="vmap", devices=1)


# ---------------------------------------------------------------------------
# padding rows: provably inert
# ---------------------------------------------------------------------------

def test_padding_rows_inert_in_states_and_metrics(setup):
    """local_update_many(pad_to=N) must return exactly the unpadded result:
    the duplicated tail rows compute but never escape collect_cohort."""
    cfg, train, _ = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    strat = get_strategy("fednano")
    mesh = client_mesh(1)
    k_server, k_clients = jax.random.split(jax.random.PRNGKey(0))
    from repro.core import server as server_lib

    server = server_lib.init_server(k_server, cfg)
    cids = sorted(train)[:3]  # 3 clients, padded to 4
    ckeys = jax.random.split(k_clients, len(cids))
    states = [strat.init_client(ck, cfg, cid, n_examples=len(train[cid]))
              for ck, cid in zip(ckeys, cids)]
    blists = [train[c] for c in cids]

    plain, pm = client_lib.local_update_many(
        cfg, server.backbone, states, blists, hp, strat,
        server.global_adapters, mesh=mesh)
    padded, qm = client_lib.local_update_many(
        cfg, server.backbone, states, blists, hp, strat,
        server.global_adapters, mesh=mesh, pad_to=4)
    assert len(padded) == len(plain) == 3
    assert pm == qm
    for s_plain, s_pad in zip(plain, padded):
        assert _tree_equal(s_plain.adapters, s_pad.adapters)
        assert _tree_equal(s_plain.fisher, s_pad.fisher)
        assert s_plain.rounds_participated == s_pad.rounds_participated


def test_pad_to_validation(setup):
    cfg, train, _ = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    strat = get_strategy("fedavg")
    mesh = client_mesh(1)
    cids = sorted(train)[:3]
    ckeys = jax.random.split(jax.random.PRNGKey(1), len(cids))
    states = [strat.init_client(ck, cfg, cid, n_examples=len(train[cid]))
              for ck, cid in zip(ckeys, cids)]
    with pytest.raises(ValueError, match="smaller than the cohort"):
        client_lib.prepare_cohort(
            cfg, states, [train[c] for c in cids], hp, strat,
            mesh=mesh, pad_to=2)


def test_zero_weight_rows_inert_in_aggregation():
    """A zero-weight row contributes exactly nothing to the weighted merge
    (x + 0.0*y == x bitwise for finite y), and an all-zero weight vector
    falls back to uniform instead of emitting NaN."""
    key = jax.random.PRNGKey(7)
    thetas = [{"w": jax.random.normal(jax.random.fold_in(key, i), (4, 3))}
              for i in range(3)]
    merged = fedavg(thetas[:2], [2.0, 3.0])
    with_zero = fedavg(thetas, [2.0, 3.0, 0.0])
    assert np.array_equal(np.asarray(merged["w"]), np.asarray(with_zero["w"]))

    w = _norm_weights([0.0, 0.0], 2)
    assert np.all(np.isfinite(np.asarray(w)))
    assert np.asarray(w) == pytest.approx([0.5, 0.5])


# ---------------------------------------------------------------------------
# checkpoint / resume on the sharded engine
# ---------------------------------------------------------------------------

def test_sharded_checkpoint_resume(setup, tmp_path):
    cfg, train, evald = setup
    full = _run(cfg, train, evald, "fednano", engine="sharded", rounds=3)
    ck = str(tmp_path / "state")
    _run(cfg, train, evald, "fednano", engine="sharded", rounds=2,
         checkpoint_dir=ck, checkpoint_every=1)
    resumed = _run(cfg, train, evald, "fednano", engine="sharded", rounds=3,
                   resume=ck)
    lf = [m["mean_loss"] for m in full.round_metrics]
    lr_ = [m["mean_loss"] for m in resumed.round_metrics]
    assert lf == pytest.approx(lr_, rel=1e-6)
    assert full.comm_totals == resumed.comm_totals
    assert float(tree_sq_norm(full.server.global_adapters)) == pytest.approx(
        float(tree_sq_norm(resumed.server.global_adapters)), rel=1e-6)


# ---------------------------------------------------------------------------
# batched client init (satellite: vmapped init_clients fast path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["fednano", "feddpa_f"])
def test_batched_init_bitwise_matches_loop(setup, name):
    """The stacked fast path must be bit-identical to K init_client calls —
    jax.random is counter-based, so vmapped draws equal sequential draws."""
    cfg, train, _ = setup
    strat = get_strategy(name)
    cids = sorted(train)
    keys = jax.random.split(jax.random.PRNGKey(3), len(cids))
    n_ex = [len(train[c]) for c in cids]
    fast = strat.init_clients(keys, cfg, cids, n_ex)
    slow = [strat.init_client(k, cfg, c, n)
            for k, c, n in zip(keys, cids, n_ex)]
    for f, s in zip(fast, slow):
        assert f.cid == s.cid and f.n_examples == s.n_examples
        assert _tree_equal(f.adapters, s.adapters)
        assert _tree_equal(f.opt_state, s.opt_state)
        if strat.dual_adapters:
            assert _tree_equal(f.local_adapters, s.local_adapters)
        else:
            assert f.local_adapters is None and s.local_adapters is None


def test_batched_init_falls_back_for_custom_strategies(setup):
    """A strategy overriding init_client (ragged/custom state) must take the
    per-client loop, not the stacked fast path."""
    cfg, train, _ = setup
    calls = []

    class Ragged(Strategy):
        def init_client(self, key, cfg, cid, n_examples):
            calls.append(cid)
            return Strategy.init_client(self, key, cfg, cid, n_examples)

    strat = Ragged()
    cids = sorted(train)
    keys = jax.random.split(jax.random.PRNGKey(3), len(cids))
    out = strat.init_clients(keys, cfg, cids, [len(train[c]) for c in cids])
    assert calls == cids  # fallback loop hit every client
    assert [s.cid for s in out] == cids


# ---------------------------------------------------------------------------
# buffered engine: seeded failure draws (satellite)
# ---------------------------------------------------------------------------

def test_buffered_failure_counters_deterministic(setup):
    from repro.core.failures import FailureModel

    cfg, train, evald = setup
    fm = FailureModel(dropout_prob=0.4, crash_prob=0.2, straggler_prob=0.3,
                      seed=11)
    kw = dict(engine="buffered", buffer_size=2, failures=fm, rounds=3)
    a = _run(cfg, train, evald, "fednano", **kw)
    b = _run(cfg, train, evald, "fednano", **kw)
    assert a.round_metrics == b.round_metrics  # seeded draws: exact replay
    for m in a.round_metrics:
        for key in ("dropped", "crashed", "straggled"):
            assert key in m and m[key] >= 0
    # with these probabilities at least one failure of each kind must show
    # up across 3 merges of 4 clients — otherwise the wiring is dead
    assert sum(m["dropped"] for m in a.round_metrics) > 0
    assert sum(m["crashed"] for m in a.round_metrics) > 0
    assert sum(m["straggled"] for m in a.round_metrics) > 0


def test_buffered_failure_resume_replay(setup, tmp_path):
    from repro.core.failures import FailureModel

    cfg, train, evald = setup
    fm = FailureModel(dropout_prob=0.3, crash_prob=0.2, straggler_prob=0.3,
                      seed=5)
    kw = dict(engine="buffered", buffer_size=2, failures=fm)
    full = _run(cfg, train, evald, "fednano", rounds=3, **kw)
    ck = str(tmp_path / "state")
    _run(cfg, train, evald, "fednano", rounds=2, checkpoint_dir=ck,
         checkpoint_every=1, **kw)
    resumed = _run(cfg, train, evald, "fednano", rounds=3, resume=ck, **kw)
    assert full.round_metrics == resumed.round_metrics
    assert full.comm_totals == resumed.comm_totals


# ---------------------------------------------------------------------------
# 8-device subprocess: six-strategy golden parity, uneven cohorts, resume
# ---------------------------------------------------------------------------

def test_sharded_eight_devices_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(HERE, "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_sharded_subproc.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, \
        f"8-device sharded checks failed:\n{proc.stdout}\n{proc.stderr}"
