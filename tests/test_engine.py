"""Round engines: vmap/scan parity, streaming merge, buffered async, and the
round-accounting / warmup-state regression fixes.

The vectorized engine must be a pure performance play: against the same
goldens as the sequential path (tests/golden/strategy_parity.json), with the
same tolerances. Everything observable — losses, accuracies, comm byte
counts, final adapter norms — is pinned.
"""
import json
import os
from dataclasses import dataclass as dc

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_centralized, run_federated
from repro.core import server as server_lib
from repro.data import make_federated_data
from repro.strategies import ClientSampler, FixedSizeSampler, UniformSampler
from repro.strategies.server_opt import FedBuffOpt
from repro.utils import tree_bytes, tree_sq_norm

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "strategy_parity.json")
LEGACY = ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft")


@pytest.fixture(scope="module")
def setup():
    # MUST mirror scripts/gen_strategy_goldens.py exactly
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=4, examples_per_client=16, alpha=1.0, batch_size=4,
        seq_len=16,
    )
    return cfg, train, evald


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _run(cfg, train, evald, strategy, hp, **kw):
    return run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                         strategy=strategy, rounds=2, hp=hp, **kw)


def _assert_matches_golden(res, want):
    got_losses = [m["mean_loss"] for m in res.round_metrics]
    assert got_losses == pytest.approx(want["round_losses"], rel=1e-6)
    assert res.avg_accuracy == pytest.approx(want["avg_accuracy"], abs=1e-9)
    for c, a in want["client_accuracy"].items():
        assert res.client_accuracy[int(c)] == pytest.approx(a, abs=1e-9)
    for k, v in want["comm_totals"].items():
        assert res.comm_totals[k] == v, (k, res.comm_totals[k], v)
    assert float(tree_sq_norm(res.server.global_adapters)) == pytest.approx(
        want["global_sq_norm"], rel=1e-6)
    assert float(tree_sq_norm(res.clients[0].adapters)) == pytest.approx(
        want["client0_sq_norm"], rel=1e-6)
    if want["client0_fisher_sq_norm"] is not None:
        assert float(tree_sq_norm(res.clients[0].fisher)) == pytest.approx(
            want["client0_fisher_sq_norm"], rel=1e-6)


# ---------------------------------------------------------------------------
# vmap engine: golden parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LEGACY)
def test_vmap_engine_matches_goldens(setup, golden, strategy):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    res = _run(cfg, train, evald, strategy, hp, engine="vmap")
    assert res.engine == "vmap"
    _assert_matches_golden(res, golden[strategy])


def test_vmap_matches_sequential_under_sampling(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2)
    sampler = UniformSampler(frac=0.5, seed=3)
    a = _run(cfg, train, evald, "fedavg", hp, sampler=sampler, engine="sequential")
    b = _run(cfg, train, evald, "fedavg", hp, sampler=sampler, engine="vmap")
    assert [m["participants"] for m in a.round_metrics] == \
           [m["participants"] for m in b.round_metrics]
    la = [m["mean_loss"] for m in a.round_metrics]
    lb = [m["mean_loss"] for m in b.round_metrics]
    assert la == pytest.approx(lb, rel=1e-6)
    assert a.comm_totals == b.comm_totals
    assert float(tree_sq_norm(a.server.global_adapters)) == pytest.approx(
        float(tree_sq_norm(b.server.global_adapters)), rel=1e-6)


@pytest.mark.smoke
def test_vmap_engine_smoke():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=2, examples_per_client=4, alpha=1.0, batch_size=2,
        seq_len=8,
    )
    hp = HyperParams(lr=5e-3, local_steps=1, fisher_batches=1)
    res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                        strategy="fednano", rounds=1, hp=hp, engine="vmap")
    assert res.round_metrics[0]["participants"] == 2
    assert res.round_metrics[0]["mean_loss"] is not None
    assert res.comm_totals["param_up"] > 0


def test_unknown_engine_rejected(setup):
    cfg, train, evald = setup
    with pytest.raises(ValueError, match="unknown engine"):
        run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                      strategy="fedavg", rounds=1, engine="pmap")


# ---------------------------------------------------------------------------
# streaming (chunked) aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["fednano", "fedavg"])
def test_streaming_merge_matches_full_merge(setup, strategy):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    full = _run(cfg, train, evald, strategy, hp, engine="vmap")
    chunked = _run(cfg, train, evald, strategy, hp, engine="vmap", agg_chunk=2)
    # summation order differs chunk-to-chunk, so tolerance not bit-exactness
    la = [m["mean_loss"] for m in full.round_metrics]
    lb = [m["mean_loss"] for m in chunked.round_metrics]
    assert la == pytest.approx(lb, rel=1e-5)
    assert float(tree_sq_norm(full.server.global_adapters)) == pytest.approx(
        float(tree_sq_norm(chunked.server.global_adapters)), rel=1e-5)
    # chunked folding must not change what crossed the wire
    assert full.comm_totals == chunked.comm_totals


def test_streaming_odd_chunk_covers_remainder(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    full = _run(cfg, train, evald, "fedavg", hp, engine="vmap")
    # 4 clients in chunks of 3 -> a full chunk plus a remainder fold
    chunked = _run(cfg, train, evald, "fedavg", hp, engine="vmap", agg_chunk=3)
    assert chunked.comm_totals["param_up"] == full.comm_totals["param_up"]
    assert float(tree_sq_norm(full.server.global_adapters)) == pytest.approx(
        float(tree_sq_norm(chunked.server.global_adapters)), rel=1e-5)


# ---------------------------------------------------------------------------
# buffered async engine (FedBuff-style)
# ---------------------------------------------------------------------------

def test_buffered_uniform_latency_degenerates_to_rounds(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                        strategy="fedavg", rounds=2, hp=hp, engine="buffered",
                        buffer_size=4)
    assert res.engine == "buffered"
    assert len(res.round_metrics) == 2
    for m in res.round_metrics:
        assert m["participants"] == 4
        # all four clients started on the same version => zero staleness
        assert m["mean_staleness"] == 0.0


def test_buffered_straggler_has_staleness(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = run_federated(
        jax.random.PRNGKey(0), cfg, train, evald, strategy="fedavg",
        rounds=3, hp=hp, engine="buffered", buffer_size=2,
        latency_fn=lambda cid, v: 5 if cid == 0 else 1,
        server_opt=FedBuffOpt(lr=0.5),
    )
    assert len(res.round_metrics) == 3
    assert all(m["participants"] == 2 for m in res.round_metrics)
    # once merges outpace the straggler, some upload must arrive stale
    assert any(m["mean_staleness"] > 0 for m in res.round_metrics)


def test_buffered_rejects_non_aggregating_strategy(setup):
    cfg, train, evald = setup
    with pytest.raises(ValueError, match="buffered"):
        run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                      strategy="locft", rounds=1, engine="buffered")


# ---------------------------------------------------------------------------
# regression: round accounting + warmup state (the bugfixes)
# ---------------------------------------------------------------------------

def test_centralized_populates_comm_totals(setup):
    cfg, train, evald = setup
    res = run_centralized(jax.random.PRNGKey(0), cfg, train, evald, steps=2,
                          hp=HyperParams(lr=5e-3))
    adapter_bytes = tree_bytes(res.clients[0].adapters)
    assert res.comm_totals["param_up"] == adapter_bytes
    assert res.comm_totals["param_down"] == adapter_bytes
    assert res.comm_totals["param_up_wire"] == adapter_bytes


def test_warmup_optimizer_state_carried_across_rounds(setup):
    # FedDPA-F used to re-init the personal-adapter AdamW every warmup round,
    # zeroing its moments; the step counter now accumulates across rounds.
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, dpa_warmup_rounds=2)
    res = _run(cfg, train, evald, "feddpa_f", hp)
    for st in res.clients:
        assert st.local_opt_state is not None
        assert int(st.local_opt_state.step) == 2 * hp.local_steps
    # and the vectorized engine threads the same state
    res_v = _run(cfg, train, evald, "feddpa_f", hp, engine="vmap")
    for st in res_v.clients:
        assert int(st.local_opt_state.step) == 2 * hp.local_steps


def test_mixed_fisher_cohort_counts_all_uploads(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1, fisher_batches=1)
    base = _run(cfg, train, evald, "fednano", hp)
    server = base.server
    thetas = [c.adapters for c in base.clients]
    fishers = [c.fisher for c in base.clients]
    sizes = [c.n_examples for c in base.clients]
    fbytes = tree_bytes(fishers[1])
    # client 0 uploads no FIM: the old `fishers[0] is not None` gate counted 0
    fishers[0] = None
    mixed = list(fishers)
    mixed_fishers = [None if f is None else f for f in mixed]
    before = server.comm.totals()["fisher_up"]
    server = server_lib.server_aggregate(server, "fedavg", thetas,
                                         mixed_fishers, sizes)
    after = server.comm.totals()["fisher_up"]
    assert after - before == fbytes * (len(thetas) - 1)


@pytest.mark.parametrize("engine", ["sequential", "vmap"])
def test_param_down_charged_to_downloaders(setup, engine):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    # LocFT downloads once (round 0) and never uploads: param_down must be
    # exactly one broadcast to each of the K clients, not zero
    res = _run(cfg, train, evald, "locft", hp, engine=engine)
    gbytes = tree_bytes(res.server.global_adapters)
    assert res.comm_totals["param_down"] == 4 * gbytes
    assert res.comm_totals["param_up"] == 0

    # under partial participation only the sampled cohort pulls the global
    sampler = FixedSizeSampler(n=2, seed=1)
    res = _run(cfg, train, evald, "fedavg", hp, engine=engine, sampler=sampler)
    expect = sum(m["participants"] for m in res.round_metrics) * gbytes
    assert res.comm_totals["param_down"] == expect
    assert res.comm_totals["param_up"] == expect  # same cohort uploads


def test_final_eval_flag_skips_eval(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedavg", hp, engine="vmap", final_eval=False)
    assert res.client_accuracy == {}
    assert res.avg_accuracy == 0.0
    assert res.comm_totals["param_up"] > 0


def test_vmap_rejects_ragged_local_steps(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=3)
    ragged = {cid: list(b) for cid, b in train.items()}
    ragged[0] = ragged[0][:1]  # client 0 has fewer batches than local_steps
    with pytest.raises(ValueError, match="sequential"):
        run_federated(jax.random.PRNGKey(0), cfg, ragged, evald,
                      strategy="fedavg", rounds=1, hp=hp, engine="vmap")
