"""Per-kernel correctness: shape/dtype sweeps, assert_allclose vs ref oracle.

All Pallas kernels run interpret=True (CPU executes the kernel body in
Python) — the target is TPU, correctness is proven here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fisher_merge import ops as fm_ops, ref as fm_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.lora import ops as lora_ops, ref as lora_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

# single source of truth for tolerances: tests/kernel_harness.py
from kernel_harness import assert_close


# ---------------------------------------------------------------------------
# lora
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5, 32), (2, 100, 128), (1, 3, 64, 256)])
@pytest.mark.parametrize("rank", [4, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_kernel(shape, rank, dtype, rng):
    d = shape[-1]
    x = jax.random.normal(rng, shape, dtype)
    down = (jax.random.normal(jax.random.fold_in(rng, 1), (d, rank)) * 0.05).astype(dtype)
    up = (jax.random.normal(jax.random.fold_in(rng, 2), (rank, d)) * 0.05).astype(dtype)
    got = lora_ops.lora_residual(x, down, up, scale=2.0, block_t=32, interpret=True)
    want = lora_ref.lora_residual(x, down, up, scale=2.0)
    assert_close(got, want, kernel="lora", dtype=dtype)


def test_lora_zero_up_is_identity(rng):
    x = jax.random.normal(rng, (4, 64))
    down = jax.random.normal(rng, (64, 8))
    up = jnp.zeros((8, 64))
    got = lora_ops.lora_residual(x, down, up, scale=2.0, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-6)


# T vs block_t coverage for the tiled kernel: exact multiple, ragged tail,
# single short block, and a tail of exactly one row.
@pytest.mark.parametrize("t,block_t", [(64, 32), (100, 32), (7, 32), (33, 32), (1, 256)])
@pytest.mark.parametrize("d,rank", [(32, 4), (128, 64), (8, 8), (64, 1)])
def test_lora_2d_ragged_tails(t, block_t, d, rank, rng):
    from repro.kernels.lora.lora import lora_residual_2d

    x = jax.random.normal(rng, (t, d))
    down = jax.random.normal(jax.random.fold_in(rng, 1), (d, rank)) * 0.05
    up = jax.random.normal(jax.random.fold_in(rng, 2), (rank, d)) * 0.05
    got = lora_residual_2d(x, down, up, scale=1.5, block_t=block_t, interpret=True)
    want = lora_ref.lora_residual(x, down, up, scale=1.5)
    assert got.shape == (t, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped (multi-tenant) lora — the serving-engine kernel
# ---------------------------------------------------------------------------

def _grouped_case(rng, t, d, rank, n, dtype=jnp.float32):
    x = jax.random.normal(rng, (t, d), dtype)
    down = (jax.random.normal(jax.random.fold_in(rng, 1), (n, d, rank)) * 0.05).astype(dtype)
    up = (jax.random.normal(jax.random.fold_in(rng, 2), (n, rank, d)) * 0.05).astype(dtype)
    # mixed ids incl. identity rows (-1); small t still sees >= 3 distinct ids
    idx = jax.random.randint(jax.random.fold_in(rng, 3), (t,), -1, n)
    return x, down, up, idx


def test_grouped_lora_all_archs(rng):
    """Grouped kernel vs per-row gather oracle at every arch's (D, rank)."""
    from repro.configs import get_smoke_config, list_archs

    seen = set()
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        key = (cfg.d_model, cfg.adapter.rank)
        if key in seen:
            continue
        seen.add(key)
        d, rank = key
        # block_t=8 over t=27 -> ragged tail AND >=3 distinct ids per block
        x, down, up, idx = _grouped_case(jax.random.fold_in(rng, hash(arch) % 997),
                                         27, d, rank, 5)
        got = lora_ops.grouped_lora_residual(
            x, down, up, idx, scale=cfg.adapter.alpha / rank, block_t=8,
            interpret=True)
        want = lora_ref.grouped_lora_residual(
            x, down, up, idx, scale=cfg.adapter.alpha / rank)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
            err_msg=f"{arch} (D={d}, r={rank})")


@pytest.mark.parametrize("t,block_t", [(64, 16), (50, 16), (3, 16), (17, 16)])
def test_grouped_lora_ragged_blocks(t, block_t, rng):
    x, down, up, idx = _grouped_case(rng, t, 64, 8, 4)
    got = lora_ops.grouped_lora_residual(
        x, down, up, idx, scale=2.0, block_t=block_t, interpret=True)
    want = lora_ref.grouped_lora_residual(x, down, up, idx, scale=2.0)
    assert got.shape == (t, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_grouped_lora_matches_single_adapter_kernel(rng):
    """Constant idx == the per-tenant kernel run with that adapter alone —
    bit-for-bit in f32 (zeroed rows stay exactly zero through two matmuls)."""
    x, down, up, _ = _grouped_case(rng, 32, 64, 8, 3)
    for n in range(3):
        idx = jnp.full((32,), n, jnp.int32)
        got = lora_ops.grouped_lora_residual(
            x, down, up, idx, scale=2.0, block_t=16, interpret=True)
        want = lora_ops.lora_residual(
            x, down[n], up[n], scale=2.0, block_t=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_lora_negative_idx_is_identity(rng):
    x, down, up, _ = _grouped_case(rng, 20, 32, 4, 3)
    idx = jnp.full((20,), -1, jnp.int32)
    got = lora_ops.grouped_lora_residual(
        x, down, up, idx, scale=2.0, block_t=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_grouped_lora_negative_idx_identity_rows_bf16(rng):
    """idx == -1 rows pass through EXACTLY in bf16 too — the kernel zeroes
    their adapter contribution rather than rounding x through the matmuls."""
    x, down, up, _ = _grouped_case(rng, 21, 32, 4, 3, dtype=jnp.bfloat16)
    idx = jax.random.randint(jax.random.fold_in(rng, 9), (21,), -1, 3)
    idx = idx.at[:5].set(-1)  # guarantee identity rows mixed into real blocks
    got = lora_ops.grouped_lora_residual(
        x, down, up, idx, scale=2.0, block_t=8, interpret=True)
    neg = np.asarray(idx) < 0
    np.testing.assert_array_equal(np.asarray(got)[neg], np.asarray(x)[neg])
    want = lora_ref.grouped_lora_residual(x, down, up, idx, scale=2.0)
    assert_close(got, want, kernel="grouped_lora", dtype=jnp.bfloat16)


@pytest.mark.parametrize("t,block_t", [(17, 16), (50, 16)])
def test_grouped_lora_mixed_block_bf16(t, block_t, rng):
    """Ragged tail blocks holding several distinct adapter ids, in bf16."""
    x, down, up, idx = _grouped_case(rng, t, 64, 8, 4, dtype=jnp.bfloat16)
    assert len(set(np.asarray(idx).tolist())) >= 3
    got = lora_ops.grouped_lora_residual(
        x, down, up, idx, scale=2.0, block_t=block_t, interpret=True)
    want = lora_ref.grouped_lora_residual(x, down, up, idx, scale=2.0)
    assert got.shape == (t, 64)
    assert_close(got, want, kernel="grouped_lora", dtype=jnp.bfloat16)


def test_grouped_lora_nd_leading_shape(rng):
    x = jax.random.normal(rng, (2, 5, 32))
    down = jax.random.normal(jax.random.fold_in(rng, 1), (3, 32, 4)) * 0.05
    up = jax.random.normal(jax.random.fold_in(rng, 2), (3, 4, 32)) * 0.05
    idx = jax.random.randint(jax.random.fold_in(rng, 3), (2, 5), -1, 3)
    got = lora_ops.grouped_lora_residual(
        x, down, up, idx, scale=1.0, block_t=4, interpret=True)
    want = lora_ref.grouped_lora_residual(x, down, up, idx, scale=1.0)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fisher merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 5, 16])
@pytest.mark.parametrize("n", [7, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fisher_merge_kernel(k, n, dtype, rng):
    t = jax.random.normal(rng, (k, n), dtype)
    f = jax.random.uniform(jax.random.fold_in(rng, 1), (k, n), minval=0.01).astype(dtype)
    w = jax.random.uniform(jax.random.fold_in(rng, 2), (k,), minval=0.1)
    got = fm_ops.fisher_merge(t, f, w, block_n=256, interpret=True)
    want = fm_ref.fisher_merge(t, f, w)
    assert_close(got, want, kernel="fisher_merge", dtype=dtype)


@pytest.mark.smoke
def test_fisher_merge_nd_leaf(rng):
    t = jax.random.normal(rng, (3, 16, 8))
    f = jax.random.uniform(rng, (3, 16, 8), minval=0.01)
    w = jnp.array([1.0, 2.0, 3.0])
    got = fm_ops.fisher_merge(t, f, w, interpret=True)
    want = fm_ref.fisher_merge(t.reshape(3, -1), f.reshape(3, -1), w).reshape(16, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # B, Sq, Sk, H, Hkv, D, causal, window, softcap
    (1, 128, 128, 4, 4, 64, True, None, 0.0),
    (2, 96, 96, 4, 2, 64, True, None, 0.0),       # GQA + ragged blocks
    (1, 256, 256, 8, 1, 64, True, 64, 0.0),       # MQA + sliding window
    (1, 1, 257, 4, 2, 64, True, None, 0.0),       # decode-style single query
    (2, 64, 64, 4, 4, 128, False, None, 0.0),     # bidirectional
    (1, 128, 128, 2, 2, 64, True, None, 30.0),    # grok softcap
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(case, dtype, rng):
    b, sq, sk, h, hkv, d, causal, window, cap = case
    q = jax.random.normal(rng, (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, sk, hkv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, sk, hkv, d), dtype)
    got = fa_ops.flash_attention(
        q, k, v, causal=causal, window=window, softcap=cap,
        block_q=64, block_k=64, interpret=True,
    )
    want = fa_ref.attention(q, k, v, causal=causal, window=window, softcap=cap)
    assert_close(got, want, kernel="flash_attention", dtype=dtype)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, H, P, N, chunk
    (1, 64, 2, 32, 16, 16),
    (2, 100, 3, 64, 32, 32),   # ragged chunking
    (1, 256, 4, 64, 128, 64),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential(case, dtype, rng):
    b, s, h, p, n, q = case
    x = (jax.random.normal(rng, (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.random.uniform(jax.random.fold_in(rng, 1), (b, s, h), minval=0.01, maxval=0.2).astype(dtype)
    A = -jax.random.uniform(jax.random.fold_in(rng, 2), (h,), minval=0.5, maxval=2.0)
    B = (jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n)) * 0.3).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(rng, 4), (b, s, n)) * 0.3).astype(dtype)
    want = ssd_ref.ssd_reference_sequential(x, dt, A, B, C)
    got = ssd_ops.ssd(x, dt, A, B, C, chunk=q, interpret=True)
    assert_close(got, want, kernel="ssd_scan_vs_sequential", dtype=dtype)


def test_ssd_chunked_oracle_matches_sequential(rng):
    b, s, h, p, n = 2, 128, 2, 16, 8
    x = jax.random.normal(rng, (b, s, h, p)) * 0.5
    dt = jax.random.uniform(rng, (b, s, h), minval=0.01, maxval=0.3)
    A = -jnp.ones((h,))
    B = jax.random.normal(rng, (b, s, n)) * 0.3
    C = jax.random.normal(rng, (b, s, n)) * 0.3
    want = ssd_ref.ssd_reference_sequential(x, dt, A, B, C)
    for chunk in (8, 32, 128):
        got = ssd_ref.ssd_chunked(x, dt, A, B, C, chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
