"""Wire-format contract: self-describing payloads, exact byte accounting.

Every upload codec produces a ``WireMessage`` stamped ``(codec, version)``;
``decode_wire`` dispatches on the stamp and refuses anything it doesn't
speak. Two invariants are pinned here:

  1. roundtrip: decode(encode(θ)) reproduces the θ the server should see,
     with shapes and dtypes preserved — for any tree shape (property tests);
  2. accounting: the bytes CommLog records as ``param_up_wire`` equal
     ``msg.nbytes`` of the message that actually crossed, both at the
     transform level and end-to-end through ``run_federated``.

Property tests use hypothesis when available and skip cleanly otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.strategies.transforms import (
    WIRE_FORMAT_VERSION,
    ClipNoiseDP,
    Int8EFQuant,
    TopKSparsify,
    TransformCtx,
    UpdateTransform,
    WireMessage,
    decode_wire,
)
from repro.utils import tree_allclose, tree_bytes, tree_sub

CTX = TransformCtx(cid=0, round_idx=0)


def _tree(shapes, scale=1.0, seed=0):
    """Deterministic float32 tree with one leaf per shape."""
    rng = np.random.RandomState(seed)
    return {f"leaf{i}": jnp.asarray(rng.randn(*s).astype(np.float32) * scale)
            for i, s in enumerate(shapes)}


# ---------------------------------------------------------------------------
# stamps: version and codec are enforced, not advisory
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_identity_encode_stamps_version():
    theta = _tree([(3, 4)])
    msg, _ = UpdateTransform().encode(CTX, theta, theta, None)
    assert msg.codec == "identity"
    assert msg.version == WIRE_FORMAT_VERSION
    assert msg.nbytes == tree_bytes(theta)
    assert tree_allclose(decode_wire(msg, theta), theta)


@pytest.mark.smoke
def test_decode_rejects_wrong_version():
    theta = _tree([(2, 2)])
    msg, _ = UpdateTransform().encode(CTX, theta, theta, None)
    with pytest.raises(ValueError, match="refusing to decode"):
        decode_wire(msg._replace(version=99), theta)


@pytest.mark.smoke
def test_decode_rejects_unknown_codec():
    theta = _tree([(2, 2)])
    with pytest.raises(ValueError, match="unknown wire codec"):
        decode_wire(WireMessage("gzip9", WIRE_FORMAT_VERSION, theta, 1), theta)


# ---------------------------------------------------------------------------
# roundtrips: unit cases for each codec
# ---------------------------------------------------------------------------

def test_int8_roundtrip_within_quantization_error():
    g = _tree([(4, 8), (16,)], seed=1)
    theta = jax.tree.map(lambda x: x + 0.05, g)
    t = Int8EFQuant()
    msg, err = t.encode(CTX, theta, g, None)
    assert msg.codec == "int8_ef"
    back = decode_wire(msg, g)
    # int8 over a ±max-scale grid: per-leaf error ≤ scale = max|delta|/127
    for k in g:
        d = np.abs(np.asarray(back[k]) - np.asarray(theta[k]))
        bound = np.abs(np.asarray(theta[k] - g[k])).max() / 127 + 1e-7
        assert d.max() <= bound
        assert back[k].dtype == theta[k].dtype
        assert back[k].shape == theta[k].shape
    # 1 byte per element + fp32 scale per leaf
    n_leaves = len(jax.tree.leaves(g))
    n_elems = sum(x.size for x in jax.tree.leaves(g))
    assert msg.nbytes == n_elems + 4 * n_leaves


def test_topk_roundtrip_keeps_exactly_k():
    g = _tree([(6, 6)], seed=2)
    theta = jax.tree.map(lambda x: x + 0.1, g)
    t = TopKSparsify(frac=0.25)
    msg, err = t.encode(CTX, theta, g, None)
    back = decode_wire(msg, g)
    k = max(1, int(round(0.25 * 36)))
    nz = int(np.count_nonzero(np.asarray(tree_sub(back, g)["leaf0"])))
    assert nz <= k  # ≤: a kept entry can legitimately be zero
    assert msg.nbytes == k * (4 + 4)
    assert back["leaf0"].shape == theta["leaf0"].shape
    assert back["leaf0"].dtype == theta["leaf0"].dtype
    # error feedback holds exactly what the wire dropped
    assert tree_allclose(jax.tree.map(jnp.add, tree_sub(back, g), err),
                         tree_sub(theta, g), atol=1e-6)


def test_dp_noiseless_is_clip_only():
    g = _tree([(3, 3)], seed=3)
    theta = jax.tree.map(lambda x: x + 1e-3, g)
    t = ClipNoiseDP(clip_norm=100.0, noise_mult=0.0)
    msg, _ = t.encode(CTX, theta, g, None)
    assert msg.codec == "dp_fp32"
    assert msg.nbytes == tree_bytes(theta)
    assert tree_allclose(decode_wire(msg, g), theta, atol=1e-6)


# ---------------------------------------------------------------------------
# roundtrips: property tests over arbitrary tree shapes
# ---------------------------------------------------------------------------

shape_lists = st.lists(
    st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1, max_size=4)


@given(shapes=shape_lists, seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_identity_roundtrip_any_shape(shapes, seed):
    theta = _tree(shapes, seed=seed)
    msg, _ = UpdateTransform().encode(CTX, theta, theta, None)
    back = decode_wire(msg, theta)
    assert tree_allclose(back, theta)
    assert msg.nbytes == tree_bytes(theta)


@given(shapes=shape_lists, seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_int8_shape_dtype_preserved_any_shape(shapes, seed):
    g = _tree(shapes, seed=seed)
    theta = jax.tree.map(lambda x: x * 1.01 + 0.01, g)
    msg, _ = Int8EFQuant().encode(CTX, theta, g, None)
    back = decode_wire(msg, g)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(theta)):
        assert a.shape == b.shape and a.dtype == b.dtype
    n_leaves = len(jax.tree.leaves(g))
    n_elems = sum(x.size for x in jax.tree.leaves(g))
    assert msg.nbytes == n_elems + 4 * n_leaves


@given(shapes=shape_lists, seed=st.integers(0, 2**16),
       frac=st.floats(0.05, 1.0))
@settings(max_examples=25, deadline=None)
def test_topk_wire_bytes_exact_any_shape(shapes, seed, frac):
    g = _tree(shapes, seed=seed)
    theta = jax.tree.map(lambda x: x + 0.5, g)
    msg, _ = TopKSparsify(frac=frac).encode(CTX, theta, g, None)
    want = sum(max(1, int(round(frac * x.size))) * (x.dtype.itemsize + 4)
               for x in jax.tree.leaves(g))
    assert msg.nbytes == want
    back = decode_wire(msg, g)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(theta)):
        assert a.shape == b.shape and a.dtype == b.dtype


# ---------------------------------------------------------------------------
# end-to-end: CommLog's param_up_wire is the encoded size, exactly
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def run_setup():
    from repro.configs import get_smoke_config
    from repro.data import make_federated_data

    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=2, examples_per_client=8, alpha=100.0, batch_size=2,
        seq_len=8,
    )
    return cfg, train, evald


@pytest.mark.parametrize("engine", ["sequential", "vmap", "buffered"])
def test_engine_wire_accounting_matches_encoding(run_setup, engine):
    from repro.core import HyperParams, run_federated

    cfg, train, evald = run_setup
    rounds = 2
    res = run_federated(
        jax.random.PRNGKey(0), cfg, train, evald, strategy="fedavg",
        rounds=rounds, hp=HyperParams(lr=5e-3, local_steps=1),
        transforms=(Int8EFQuant(),), engine=engine,
        buffer_size=len(train) if engine == "buffered" else None,
        final_eval=False,
    )
    g = res.server.global_adapters
    n_leaves = len(jax.tree.leaves(g))
    n_elems = sum(x.size for x in jax.tree.leaves(g))
    per_upload = n_elems + 4 * n_leaves
    n_uploads = sum(m["participants"] for m in res.round_metrics)
    assert res.comm_totals["param_up_wire"] == per_upload * n_uploads
    # and dense accounting is untouched by the wire codec
    assert res.comm_totals["param_up"] == tree_bytes(g) * n_uploads
