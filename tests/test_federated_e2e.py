"""End-to-end federated behaviour (integration tests).

Uses a tiny 2-layer backbone; asserts protocol-level invariants rather than
absolute accuracies (those live in benchmarks/): loss decreases, strategies
run, FedProx constrains drift, FedDPA-F keeps personal adapters local,
comm accounting matches the adapter sizes, checkpoints round-trip.
"""
import dataclasses
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_centralized, run_federated
from repro.core.comm import adapter_upload_params
from repro.data import make_federated_data
from repro.utils import tree_bytes, tree_sq_norm, tree_sub


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=3, examples_per_client=24, alpha=1.0, batch_size=4, seq_len=20
    )
    return cfg, train, evald


@pytest.mark.parametrize("strategy", ["fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft"])
def test_strategy_runs_and_loss_decreases(setup, strategy, rng):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=4, fisher_batches=2)
    res = run_federated(rng, cfg, train, evald, strategy=strategy, rounds=3, hp=hp)
    losses = [m["mean_loss"] for m in res.round_metrics]
    assert losses[-1] < losses[0], f"{strategy}: loss did not decrease {losses}"
    assert 0.0 <= res.avg_accuracy <= 1.0
    if strategy != "locft":
        assert res.comm_totals["param_up"] > 0


def test_fednano_comm_accounting(setup, rng):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=1)
    rounds, k = 2, len(train)
    res = run_federated(rng, cfg, train, evald, strategy="fednano", rounds=rounds, hp=hp)
    n_params = adapter_upload_params(cfg)
    want_up = rounds * k * n_params * 4  # f32 adapters
    assert res.comm_totals["param_up"] == want_up
    assert res.comm_totals["fisher_up"] == want_up  # diag FIM same shape
    assert res.comm_totals["param_down"] == want_up


def test_fedprox_constrains_drift(setup, rng):
    """With a huge μ the local update must stay closer to the global init."""
    cfg, train, evald = setup
    drift = {}
    for mu in (0.0, 100.0):
        hp = HyperParams(lr=5e-3, local_steps=6, prox_mu=mu)
        strategy = "fedprox" if mu else "fedavg"
        res = run_federated(rng, cfg, train, evald, strategy=strategy, rounds=1, hp=hp)
        server = res.server
        # distance between merged params and fresh init-distributed params:
        # use first client's end-of-round params vs the round's start (zeros up)
        c0 = res.clients[0]
        drift[mu] = float(tree_sq_norm(c0.adapters))
    assert drift[100.0] < drift[0.0], drift


def test_feddpa_local_adapters_stay_personal(setup, rng):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=3, dpa_warmup_rounds=1)
    res = run_federated(rng, cfg, train, evald, strategy="feddpa_f", rounds=2, hp=hp)
    locs = [c.local_adapters for c in res.clients]
    assert all(l is not None for l in locs)
    # personal adapters must differ across clients (they never aggregate)
    d = tree_sq_norm(tree_sub(locs[0], locs[1]))
    assert float(d) > 0.0


def test_fednano_ef_skips_extra_pass(setup, rng):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=3)
    res = run_federated(rng, cfg, train, evald, strategy="fednano_ef", rounds=1, hp=hp)
    assert res.clients[0].fisher is not None
    # EF fisher must be positive (eps floor) and finite
    leaves = jax.tree.leaves(res.clients[0].fisher)
    assert all(bool(jnp.all(l > 0)) and bool(jnp.all(jnp.isfinite(l))) for l in leaves)


def test_centralized_runs(setup, rng):
    cfg, train, evald = setup
    res = run_centralized(rng, cfg, train, evald, steps=8, hp=HyperParams(lr=5e-3))
    assert res.round_metrics and 0.0 <= res.avg_accuracy <= 1.0


def test_server_checkpoint_roundtrip(setup, rng, tmp_path):
    from repro.checkpoint import load_server_checkpoint, save_server_checkpoint
    from repro.utils import tree_allclose

    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2)
    res = run_federated(rng, cfg, train, evald, strategy="fednano", rounds=1, hp=hp)
    save_server_checkpoint(str(tmp_path / "ckpt"), res.server, round_idx=1)
    import dataclasses as dc

    blank = dc.replace(
        res.server,
        global_adapters=jax.tree.map(jnp.zeros_like, res.server.global_adapters),
    )
    restored, meta = load_server_checkpoint(str(tmp_path / "ckpt"), blank)
    assert meta["round_idx"] == 1
    assert tree_allclose(restored.global_adapters, res.server.global_adapters)
