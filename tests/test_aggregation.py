"""Fisher-merge / FedAvg properties — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core.aggregation import aggregate, fedavg, fisher_merge
from repro.utils import tree_allclose


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "text": {"down": jax.random.normal(k1, (8, 4)) * scale,
                 "up": jax.random.normal(k2, (4, 8)) * scale},
    }


def test_fedavg_equal_weights_is_mean(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    merged = fedavg(trees, None)
    want = jax.tree.map(lambda *xs: sum(xs) / 3, *trees)
    assert tree_allclose(merged, want, rtol=1e-6)


def test_fedavg_weighted(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
    merged = fedavg(trees, [3, 1])
    want = jax.tree.map(lambda a, b: 0.75 * a + 0.25 * b, *trees)
    assert tree_allclose(merged, want, rtol=1e-6)


def test_fisher_merge_k1_identity(rng):
    t = _tree(rng)
    f = jax.tree.map(lambda x: jnp.abs(x) + 0.1, t)
    merged = fisher_merge([t], [f], [5])
    assert tree_allclose(merged, t, rtol=1e-5, atol=1e-5)


def test_fisher_merge_equal_fisher_reduces_to_fedavg(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    ones = jax.tree.map(jnp.ones_like, trees[0])
    merged = fisher_merge(trees, [ones] * 3, [1, 2, 3])
    want = fedavg(trees, [1, 2, 3])
    assert tree_allclose(merged, want, rtol=1e-5, atol=1e-6)


def test_fisher_merge_dominant_fisher_wins(rng):
    """A client with overwhelming Fisher mass should dominate the merge."""
    t1, t2 = _tree(rng), _tree(jax.random.fold_in(rng, 1))
    big = jax.tree.map(lambda x: jnp.full_like(x, 1e6), t1)
    small = jax.tree.map(lambda x: jnp.full_like(x, 1e-6), t2)
    merged = fisher_merge([t1, t2], [big, small], None)
    assert tree_allclose(merged, t1, rtol=1e-3, atol=1e-4)


def test_fisher_merge_permutation_invariant(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    fs = [jax.tree.map(lambda x: jnp.abs(x) + 0.5, t) for t in trees]
    m1 = fisher_merge(trees, fs, [1, 2, 3])
    m2 = fisher_merge(trees[::-1], fs[::-1], [3, 2, 1])
    assert tree_allclose(m1, m2, rtol=1e-5, atol=1e-6)


def test_fisher_merge_fisher_scale_invariant(rng):
    """Multiplying every F_k by the same constant must not change Eq. 1."""
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
    fs = [jax.tree.map(lambda x: jnp.abs(x) + 0.5, t) for t in trees]
    fs_scaled = [jax.tree.map(lambda x: x * 1000.0, f) for f in fs]
    m1 = fisher_merge(trees, fs, [1, 1])
    m2 = fisher_merge(trees, fs_scaled, [1, 1])
    assert tree_allclose(m1, m2, rtol=1e-4, atol=1e-5)


def test_pallas_path_matches_jnp_path(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(4)]
    fs = [jax.tree.map(lambda x: jnp.abs(x) + 0.2, t) for t in trees]
    m1 = fisher_merge(trees, fs, [1, 2, 3, 4], use_pallas=False)
    m2 = fisher_merge(trees, fs, [1, 2, 3, 4], use_pallas=True)
    assert tree_allclose(m1, m2, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    vals=st.lists(
        st.lists(st.floats(-10, 10), min_size=4, max_size=4),
        min_size=2, max_size=5,
    ),
    fish=st.lists(
        st.lists(st.floats(1e-3, 1e3), min_size=4, max_size=4),
        min_size=2, max_size=5,
    ),
)
def test_merge_within_convex_hull(vals, fish):
    """Eq. 1 is a convex combination per coordinate: the merged value lies in
    [min_k θ_k, max_k θ_k] elementwise (up to eps slack)."""
    k = min(len(vals), len(fish))
    thetas = [{"w": jnp.asarray(v[:4], jnp.float32)} for v in vals[:k]]
    fishers = [{"w": jnp.asarray(f[:4], jnp.float32)} for f in fish[:k]]
    merged = fisher_merge(thetas, fishers, None)["w"]
    lo = jnp.min(jnp.stack([t["w"] for t in thetas]), axis=0)
    hi = jnp.max(jnp.stack([t["w"] for t in thetas]), axis=0)
    assert bool(jnp.all(merged >= lo - 1e-3)), (merged, lo)
    assert bool(jnp.all(merged <= hi + 1e-3)), (merged, hi)


@pytest.mark.smoke
def test_aggregate_registry(rng):
    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(2)]
    fs = [jax.tree.map(jnp.ones_like, t) for t in trees]
    assert aggregate("locft", trees, fs, [1, 1]) is None
    for s in ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f"):
        out = aggregate(s, trees, fs, [1, 1])
        assert out is not None
    with pytest.raises(ValueError):
        aggregate("nope", trees, fs, [1, 1])


# ---------------------------------------------------------------------------
# streaming Fisher merge (FedNano.agg_stream_*): O(1) server memory
# ---------------------------------------------------------------------------

def _no_stack_allowed(monkeypatch):
    """Make every tree_stack alias explode: the streaming path must never
    materialize a (K, ...) per-client stack."""
    import repro.core.aggregation as agg_mod
    import repro.core.client as client_mod
    import repro.utils as utils_mod
    import repro.utils.tree as tree_mod

    def boom(*a, **k):
        raise AssertionError("streaming merge materialized a client stack")

    for mod in (tree_mod, utils_mod, agg_mod, client_mod):
        monkeypatch.setattr(mod, "tree_stack", boom)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("chunking", [[4], [1, 3], [2, 1, 1], [1, 1, 1, 1]])
def test_fednano_streaming_matches_materializing(rng, monkeypatch, use_pallas,
                                                 chunking):
    from repro.strategies import get_strategy

    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(4)]
    fishers = [jax.tree.map(lambda x: jnp.abs(x) + 0.2, t) for t in trees]
    weights = [1.0, 2.0, 3.0, 4.0]
    want = fisher_merge(trees, fishers, weights, use_pallas=False)

    _no_stack_allowed(monkeypatch)  # AFTER the materializing oracle ran
    strat = get_strategy("fednano")
    acc, i = None, 0
    for size in chunking:
        acc = strat.agg_stream_fold(
            acc, trees[i:i + size], fishers[i:i + size], weights[i:i + size],
            use_pallas=use_pallas)
        i += size
    got = strat.agg_stream_finalize(acc, use_pallas=use_pallas)
    assert tree_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fednano_streaming_order_invariant(rng, monkeypatch):
    """Folding clients in any arrival order gives the same merge (mod fp)."""
    from repro.strategies import get_strategy

    trees = [_tree(jax.random.fold_in(rng, i)) for i in range(3)]
    fishers = [jax.tree.map(lambda x: jnp.abs(x) + 0.1, t) for t in trees]
    _no_stack_allowed(monkeypatch)
    strat = get_strategy("fednano")

    def run(order):
        acc = None
        for i in order:
            acc = strat.agg_stream_fold(acc, [trees[i]], [fishers[i]], [i + 1.0])
        return strat.agg_stream_finalize(acc)

    assert tree_allclose(run([0, 1, 2]), run([2, 0, 1]), rtol=1e-6, atol=1e-6)


def test_fednano_streaming_requires_fisher(rng):
    from repro.strategies import get_strategy

    trees = [_tree(rng)]
    with pytest.raises(ValueError):
        get_strategy("fednano").agg_stream_fold(None, trees, [None], [1.0])
