"""Schema tests for the pinned BENCH_*.json perf trajectories at repo root.

These files are the repo's perf history — a PR that breaks their shape (or
rewrites history in the append-only kernel trajectory) silently destroys
the ability to diff perf across PRs, so the schema is enforced here.
"""
import json
import math
import os

import pytest

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

KERNEL_FAMILIES = {"lora", "grouped_lora", "fisher_merge",
                   "fisher_merge_stream", "flash_attention", "ssd_scan"}


def _load(name):
    path = os.path.join(ROOT, name)
    assert os.path.exists(path), f"{name} missing from repo root"
    with open(path) as f:
        return json.load(f)


def _assert_finite_number(row, key, ctx):
    assert key in row, f"{ctx}: missing required key {key!r} in {sorted(row)}"
    v = row[key]
    assert isinstance(v, (int, float)) and not isinstance(v, bool), \
        f"{ctx}: {key}={v!r} is not a number"
    assert math.isfinite(v), f"{ctx}: {key}={v!r} is not finite"


# ---------------------------------------------------------------------------
# common shape: {"config": {...}, "results": [...]}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["BENCH_kernels.json", "BENCH_engine.json",
                                  "BENCH_serve.json"])
def test_bench_doc_shape(name):
    doc = _load(name)
    assert set(doc) == {"config", "results"}, f"{name}: top-level keys {sorted(doc)}"
    assert isinstance(doc["config"], dict) and doc["config"]
    assert isinstance(doc["results"], list) and doc["results"], \
        f"{name}: results must be a non-empty list"


# ---------------------------------------------------------------------------
# BENCH_kernels.json — the append-only trajectory
# ---------------------------------------------------------------------------

def test_kernels_rows():
    doc = _load("BENCH_kernels.json")
    for i, row in enumerate(doc["results"]):
        ctx = f"BENCH_kernels.json results[{i}]"
        assert row.get("kernel") in KERNEL_FAMILIES, \
            f"{ctx}: unknown kernel {row.get('kernel')!r}"
        assert isinstance(row.get("shape"), dict) and row["shape"], ctx
        for dim, v in row["shape"].items():
            assert isinstance(v, int) and v > 0, f"{ctx}: shape[{dim}]={v!r}"
        assert isinstance(row.get("label"), str) and row["label"], ctx
        assert row.get("bound") in ("compute", "memory"), ctx
        for key in ("interpret_ms", "ref_ms", "roofline_us"):
            _assert_finite_number(row, key, ctx)
            assert row[key] >= 0, f"{ctx}: {key} negative"
        _assert_finite_number(row, "seq", ctx)


def test_kernels_every_family_present():
    doc = _load("BENCH_kernels.json")
    seen = {r["kernel"] for r in doc["results"]}
    missing = KERNEL_FAMILIES - seen
    assert not missing, f"BENCH_kernels.json missing families: {sorted(missing)}"


def test_kernels_append_only_ordering():
    """seq must be non-decreasing down the file (append-only history), start
    at 1, and have no gaps between consecutive run groups."""
    doc = _load("BENCH_kernels.json")
    seqs = [r["seq"] for r in doc["results"]]
    assert all(isinstance(s, int) and s >= 1 for s in seqs)
    assert seqs == sorted(seqs), "rows are not in append order (seq decreased)"
    runs = sorted(set(seqs))
    assert runs[0] == 1 and runs == list(range(1, len(runs) + 1)), \
        f"seq groups have gaps: {runs}"


def test_kernels_config_pins_roofline():
    cfg = _load("BENCH_kernels.json")["config"]
    for key in ("device", "roofline", "schema"):
        assert key in cfg
    _assert_finite_number(cfg["roofline"], "peak_flops_bf16", "config.roofline")
    _assert_finite_number(cfg["roofline"], "hbm_bw", "config.roofline")


# ---------------------------------------------------------------------------
# BENCH_engine.json / BENCH_serve.json — keyed-row documents
# ---------------------------------------------------------------------------

def test_engine_rows():
    """Rows are keyed by (clients, devices) — single-host rows (no
    ``devices`` field, or 1) compare sequential vs vmap; multi-device rows
    compare vmap vs the sharded engine with the double buffer on AND off,
    and must be labeled with the run + speedup mechanism."""
    doc = _load("BENCH_engine.json")
    keys = [(r["clients"], r.get("devices", 1)) for r in doc["results"]]
    assert keys == sorted(keys) and len(set(keys)) == len(keys), \
        "engine rows must be unique and sorted by (clients, devices)"
    for i, row in enumerate(doc["results"]):
        ctx = f"BENCH_engine.json results[{i}]"
        assert isinstance(row.get("strategy"), str), ctx
        if row.get("devices", 1) > 1:
            for key in ("vmap_per_round_s", "sharded_per_round_s",
                        "sharded_no_overlap_per_round_s", "setup_s",
                        "speedup", "overlap_gain"):
                _assert_finite_number(row, key, ctx)
            assert isinstance(row["devices"], int) and row["devices"] > 1, ctx
            assert isinstance(row.get("label"), str) and row["label"], \
                f"{ctx}: sharded rows must carry a run label"
            assert isinstance(row.get("mechanism"), str) and row["mechanism"], \
                f"{ctx}: sharded rows must explain the speedup mechanism"
        else:
            for key in ("sequential_per_round_s", "vmap_per_round_s",
                        "speedup"):
                _assert_finite_number(row, key, ctx)


def test_serve_rows():
    doc = _load("BENCH_serve.json")
    keys = [(r["tenants"], r["requests"]) for r in doc["results"]]
    assert keys == sorted(keys) and len(set(keys)) == len(keys), \
        "serve rows must be unique and sorted by (tenants, requests)"
    for i, row in enumerate(doc["results"]):
        ctx = f"BENCH_serve.json results[{i}]"
        for key in ("engine_s", "naive_s", "engine_tok_s", "naive_tok_s",
                    "speedup", "total_tokens"):
            _assert_finite_number(row, key, ctx)
