import jax
import pytest

# Tests run on the real 1-CPU topology (the 512-device flag belongs ONLY to
# repro.launch.dryrun). Keep everything float32 + tiny.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
