"""Strategy-plugin API: registry, legacy parity, transforms, server opts.

The parity goldens (tests/golden/strategy_parity.json) were captured on the
PRE-plugin string-dispatch implementation; asserting the registry path
reproduces them proves the refactor changed zero numerics.
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_centralized, run_federated
from repro.data import make_federated_data
from repro.strategies import (
    ClientSampler,
    FedNano,
    Strategy,
    TopKSparsify,
    UniformSampler,
    available_strategies,
    get_strategy,
    register,
)
from repro.strategies.server_opt import FedAdamOpt, FedAvgMOpt
from repro.utils import tree_sq_norm

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "strategy_parity.json")
LEGACY = ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft")


@pytest.fixture(scope="module")
def setup():
    # MUST mirror scripts/gen_strategy_goldens.py exactly
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=4, examples_per_client=16, alpha=1.0, batch_size=4,
        seq_len=16,
    )
    return cfg, train, evald


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _run(cfg, train, evald, strategy, hp, **kw):
    return run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                         strategy=strategy, rounds=2, hp=hp, **kw)


def _assert_matches_golden(res, want):
    got_losses = [m["mean_loss"] for m in res.round_metrics]
    assert got_losses == pytest.approx(want["round_losses"], rel=1e-6)
    assert res.avg_accuracy == pytest.approx(want["avg_accuracy"], abs=1e-9)
    for c, a in want["client_accuracy"].items():
        assert res.client_accuracy[int(c)] == pytest.approx(a, abs=1e-9)
    for k, v in want["comm_totals"].items():
        assert res.comm_totals[k] == v, (k, res.comm_totals[k], v)
    assert float(tree_sq_norm(res.server.global_adapters)) == pytest.approx(
        want["global_sq_norm"], rel=1e-6)
    assert float(tree_sq_norm(res.clients[0].adapters)) == pytest.approx(
        want["client0_sq_norm"], rel=1e-6)
    if want["client0_fisher_sq_norm"] is not None:
        assert float(tree_sq_norm(res.clients[0].fisher)) == pytest.approx(
            want["client0_fisher_sq_norm"], rel=1e-6)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_registry_lists_all_legacy_strategies():
    names = available_strategies()
    for s in LEGACY:
        assert s in names


@pytest.mark.smoke
def test_unknown_strategy_lists_registered():
    with pytest.raises(ValueError) as ei:
        get_strategy("definitely_not_a_strategy")
    msg = str(ei.value)
    for s in LEGACY:
        assert s in msg, f"error message should list {s}: {msg}"


@pytest.mark.smoke
def test_get_strategy_passthrough_and_equality():
    s = FedNano()
    assert get_strategy(s) is s
    assert get_strategy("fednano") == s          # value-equal frozen dataclass
    assert hash(get_strategy("fednano")) == hash(s)


@pytest.mark.smoke
def test_register_custom_strategy_roundtrip():
    @register("_test_custom")
    class Custom(Strategy):
        pass

    try:
        assert isinstance(get_strategy("_test_custom"), Custom)
        assert get_strategy("_test_custom").name == "_test_custom"
    finally:
        from repro.strategies.base import _REGISTRY

        _REGISTRY.pop("_test_custom", None)


# ---------------------------------------------------------------------------
# legacy parity (seeded, 2 rounds, 4 clients)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LEGACY)
def test_registry_matches_legacy_goldens(setup, golden, strategy):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    res = _run(cfg, train, evald, strategy, hp)
    _assert_matches_golden(res, golden[strategy])


def test_transform_pipeline_matches_legacy_dp_int8(setup, golden):
    """The composable DP→int8 chain reproduces the old inline blocks."""
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2,
                     dp_clip=1.0, dp_noise=0.01, compress_uploads=True)
    res = _run(cfg, train, evald, "fednano", hp)
    _assert_matches_golden(res, golden["fednano+dp+int8"])


def test_string_and_instance_paths_identical(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1, fisher_batches=1)
    a = _run(cfg, train, evald, "fednano", hp)
    b = _run(cfg, train, evald, FedNano(), hp)
    assert [m["mean_loss"] for m in a.round_metrics] == \
           [m["mean_loss"] for m in b.round_metrics]
    assert a.client_accuracy == b.client_accuracy


# ---------------------------------------------------------------------------
# extensibility: new methods without touching the engine
# ---------------------------------------------------------------------------

def test_fedadam_runs_end_to_end(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedadam", hp)
    base = _run(cfg, train, evald, "fedavg", hp)
    assert 0.0 <= res.avg_accuracy <= 1.0
    # the adaptive server step must actually move the global params away
    # from the plain-averaged trajectory
    d = float(tree_sq_norm(jax.tree.map(
        lambda a, b: a - b, res.server.global_adapters,
        base.server.global_adapters)))
    assert d > 0.0


def test_server_opt_as_explicit_arg(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedavg", hp, server_opt=FedAvgMOpt(lr=0.5))
    assert len(res.round_metrics) == 2
    assert all(jnp.isfinite(jnp.asarray(m["mean_loss"])) for m in res.round_metrics)


def test_topk_transform_cuts_wire_bytes(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedavg", hp, transforms=(TopKSparsify(frac=0.25),))
    ct = res.comm_totals
    assert 0 < ct["param_up_wire"] < ct["param_up"]
    # top-k keeps 25% of entries at 8 bytes each vs 100% at 4 bytes => 50%
    assert ct["param_up_wire"] == ct["param_up"] // 2


def test_uniform_sampler_partial_participation(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedavg", hp,
               sampler=UniformSampler(frac=0.5, seed=3))
    assert all(m["participants"] == 2 for m in res.round_metrics)  # 0.5 * 4
    assert len(res.client_accuracy) == 4  # everyone still evaluates


def test_feddpa_warmup_follows_participation_not_round(setup):
    """A client first sampled after the warmup round must still warm up its
    personal adapter on ITS first round (warmup keys on participation)."""
    from dataclasses import dataclass as dc

    @dc(frozen=True)
    class Staggered(ClientSampler):
        def select(self, round_idx, cids):
            return [0, 1] if round_idx == 0 else list(cids)

    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=2, dpa_warmup_rounds=1)
    res = _run(cfg, train, evald, "feddpa_f", hp, sampler=Staggered())
    # clients 2,3 first participate at round 1 — their personal adapters
    # must still have been trained (LoRA 'up' leaves move off zero-init)
    for c in res.clients:
        up_norm = float(tree_sq_norm(jax.tree.map(
            lambda a: a, c.local_adapters["text"]["up"])))
        assert up_norm > 0.0, f"client {c.cid} personal adapter never warmed up"


def test_empty_cohort_round_is_skipped_gracefully(setup):
    from dataclasses import dataclass as dc

    @dc(frozen=True)
    class EveryOther(ClientSampler):
        def select(self, round_idx, cids):
            return [] if round_idx == 0 else list(cids)

    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=1)
    res = _run(cfg, train, evald, "fedavg", hp, sampler=EveryOther())
    assert res.round_metrics[0]["participants"] == 0
    # an empty round has no loss — None, not a fake 0.0 that would drag
    # averages toward zero downstream
    assert res.round_metrics[0]["mean_loss"] is None
    assert res.round_metrics[1]["participants"] == 4
    observed = [m["mean_loss"] for m in res.round_metrics if m["mean_loss"] is not None]
    assert observed and all(x == x for x in observed)  # NaN-free


@pytest.mark.smoke
def test_sampler_selection_shapes():
    cids = [0, 1, 2, 3, 4]
    assert ClientSampler().select(0, cids) == cids
    picked = UniformSampler(frac=0.4, seed=0).select(1, cids)
    assert len(picked) == 2 and picked == sorted(set(picked))
    assert set(picked) <= set(cids)
    # deterministic in (seed, round)
    assert picked == UniformSampler(frac=0.4, seed=0).select(1, cids)


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------

def test_zero_local_steps_metrics_are_finite(setup):
    cfg, train, evald = setup
    hp = HyperParams(lr=5e-3, local_steps=0, fisher_batches=1)
    res = _run(cfg, train, evald, "fednano", hp)
    for m in res.round_metrics:
        assert m["mean_loss"] == 0.0


def test_centralized_splits_server_and_client_keys(setup):
    """Server init must consume a split of the key, not the raw key (the
    synthetic single client gets the other half)."""
    from repro.core import server as server_lib
    from repro.utils import tree_allclose

    cfg, train, evald = setup
    res = run_centralized(jax.random.PRNGKey(0), cfg, train, evald, steps=1,
                          hp=HyperParams(lr=5e-3))
    k_server, _ = jax.random.split(jax.random.PRNGKey(0))
    want = server_lib.init_server(k_server, cfg)
    reused = server_lib.init_server(jax.random.PRNGKey(0), cfg)
    assert tree_allclose(res.server.global_adapters, want.global_adapters)
    assert not tree_allclose(res.server.global_adapters, reused.global_adapters)
