"""Split-learning runtime: the wire-factored gradient must equal end-to-end
jax.grad, and the activation byte accounting must match the analytic model."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import Batch, adapters as A
from repro.core.split import split_activation_bytes_per_step, split_train_grads
from repro.utils import tree_allclose


def _setup(arch, rng, b=2, s=12):
    cfg = get_smoke_config(arch)
    from repro.models import model as M
    from repro.models.vision_stub import num_patches

    backbone = M.init_backbone(rng, cfg)
    adp = A.init_nanoedge(rng, cfg)
    patches = None
    if cfg.frontend_dim:
        m = cfg.enc_seq_len if cfg.family == "audio" else num_patches(cfg)
        patches = jax.random.normal(rng, (b, m, cfg.frontend_dim))
    batch = Batch(
        tokens=jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
        labels=jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
        mask=jnp.ones((b, s), jnp.float32),
        patches=patches,
    )
    return cfg, backbone, adp, batch


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "llava-1.5-7b", "whisper-base"])
def test_split_grads_equal_fused_grads(arch, rng):
    cfg, backbone, adp, batch = _setup(arch, rng)
    # make the adapter non-trivial so gradients flow through both halves
    adp = jax.tree.map(lambda x: x + 0.01, adp)

    loss_split, grads_split, traffic = split_train_grads(cfg, backbone, adp, batch)
    loss_fused, grads_fused = jax.value_and_grad(
        lambda a: A.fednano_loss(cfg, backbone, a, batch)[0]
    )(adp)

    assert abs(float(loss_split) - float(loss_fused)) < 1e-5
    assert tree_allclose(grads_split, grads_fused, rtol=1e-4, atol=1e-6), (
        "split-learning gradient != fused gradient"
    )
    assert traffic["act_up"] > 0 and traffic["act_down"] > 0


def test_activation_traffic_matches_analytic(rng):
    cfg, backbone, adp, batch = _setup("h2o-danube-1.8b", rng, b=2, s=12)
    _, _, traffic = split_train_grads(cfg, backbone, adp, batch)
    # embeds are (B, S, D) in the param dtype (f32 smoke); grads f32
    want = 2 * 12 * cfg.d_model * 4
    assert traffic["act_up"] == want
    assert traffic["act_down"] == want
    est = split_activation_bytes_per_step(cfg.with_(dtype="float32"), 2, 12)
    assert est["act_up"] == want


@pytest.mark.parametrize(
    "arch",
    ["llava-1.5-7b", "minigpt4-7b", "qwen2-vl-72b", "whisper-base",
     "h2o-danube-1.8b", "mamba2-130m"],
)
def test_activation_traffic_analytic_all_archs(arch, rng):
    """The analytic estimate must equal the MEASURED wire traffic on every
    arch — including the encoder stream (image prefix / audio memory) that
    the pre-fix formula dropped on multimodal archs."""
    cfg, backbone, adp, batch = _setup(arch, rng, b=2, s=12)
    _, _, traffic = split_train_grads(cfg, backbone, adp, batch)
    est = split_activation_bytes_per_step(cfg.with_(dtype="float32"), 2, 12)
    assert est["act_up"] == traffic["act_up"], (
        f"{arch}: analytic up {est['act_up']} != measured {traffic['act_up']}")
    assert est["act_down"] == traffic["act_down"], (
        f"{arch}: analytic down {est['act_down']} != measured "
        f"{traffic['act_down']}")


def test_activation_traffic_analytic_text_only_override():
    """n_patches=0 recovers the text-only wire cost on a multimodal arch."""
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("llava-1.5-7b").with_(dtype="float32")
    est = split_activation_bytes_per_step(cfg, 2, 12, n_patches=0)
    assert est["act_up"] == 2 * 12 * cfg.d_model * 4
