"""Drives the differential-test harness (tests/kernel_harness.py).

Grid parity for every registered kernel family, gradient parity for the
families with custom VJPs, and hypothesis property tests (randomized shapes)
that degrade to skips through tests/_hypothesis_stub.py when hypothesis is
not installed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

import kernel_harness as kh

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_stub import given, settings, st

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# registry sanity
# --------------------------------------------------------------------------

def test_all_families_registered():
    fams = kh.kernel_families()
    for required in ("lora", "grouped_lora", "flash_attention", "fisher_merge",
                     "fisher_merge_stream", "ssd_scan"):
        assert required in fams, f"{required} missing from harness registry"


def test_grid_covers_block_boundaries():
    # every family's grid must include a below-block, exact-block and
    # above-block case — the contract the harness exists to enforce
    assert {31, 32, 33} <= {t for t, *_ in kh.LORA_SHAPES}
    assert {15, 16, 17} <= {t for t, *_ in kh.GROUPED_LORA_SHAPES}
    assert {15, 16, 17} <= {sq for _, _, sq, *_ in kh.FLASH_SHAPES}
    assert {255, 256, 257} <= {n for _, n, _ in kh.FISHER_SHAPES}
    assert {15, 16, 17} <= {s for _, s, *_ in kh.SSD_SHAPES}


def test_smoke_cases_one_per_family():
    cases = kh.smoke_cases()
    assert len(cases) == len(kh.kernel_families())
    assert sorted({c.kernel for c in cases}) == sorted(kh.kernel_families())


@pytest.mark.smoke
def test_kernel_parity_smoke():
    """One harness case per family — the <20s pre-commit parity gate
    (scripts/smoke.sh runs pytest -m smoke)."""
    for case in kh.smoke_cases():
        kh.check_case(case, jax.random.fold_in(KEY, hash(case.id) % (1 << 30)))


# --------------------------------------------------------------------------
# the grid: parity for every (family, shape, dtype) case
# --------------------------------------------------------------------------

@pytest.mark.parametrize("case", kh.all_cases(), ids=lambda c: c.id)
def test_kernel_parity(case):
    kh.check_case(case, jax.random.fold_in(KEY, hash(case.id) % (1 << 30)))


@pytest.mark.parametrize("case", kh.all_grad_cases(), ids=lambda c: c.id)
def test_kernel_grad_parity(case):
    kh.check_grad_case(case, jax.random.fold_in(KEY, hash(case.id) % (1 << 30)))


# --------------------------------------------------------------------------
# property-based differential tests (hypothesis, or skipped via the stub)
# --------------------------------------------------------------------------

_DTYPE = st.sampled_from(["float32", "bfloat16"])


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 80), d=st.integers(1, 12), r=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1), dtype=_DTYPE)
def test_lora_property(t, d, r, seed, dtype):
    d = d * 8  # keep lane dim reasonable while still odd-multiple
    case = kh.Case("lora", f"prop-t{t}d{d}r{r}", dtype,
                   kh._lora_case(t, d, r, 32, jnp.dtype(dtype)))
    kh.check_case(case, jax.random.PRNGKey(seed))


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 64), n=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1), dtype=_DTYPE)
def test_grouped_lora_property(t, n, seed, dtype):
    case = kh.Case("grouped_lora", f"prop-t{t}n{n}", dtype,
                   kh._grouped_case(t, 32, 4, n, 16, jnp.dtype(dtype)))
    kh.check_case(case, jax.random.PRNGKey(seed))


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(1, 48), extra=st.integers(0, 32), h=st.sampled_from([1, 2, 4]),
       causal=st.booleans(), seed=st.integers(0, 2**31 - 1), dtype=_DTYPE)
def test_flash_property(sq, extra, h, causal, seed, dtype):
    sk = sq + extra  # kv length >= query length keeps causal offsets valid
    shape = ("prop", 1, sq, sk, h, h, 32, causal, None, 0.0, 16, 16)
    case = kh.Case("flash_attention", f"prop-sq{sq}sk{sk}h{h}", dtype,
                   kh._flash_case(shape, jnp.dtype(dtype)))
    kh.check_case(case, jax.random.PRNGKey(seed))


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 8), n=st.integers(1, 600),
       seed=st.integers(0, 2**31 - 1), dtype=_DTYPE)
def test_fisher_property(k, n, seed, dtype):
    case = kh.Case("fisher_merge", f"prop-k{k}n{n}", dtype,
                   kh._fisher_case(k, n, 256, jnp.dtype(dtype)))
    kh.check_case(case, jax.random.PRNGKey(seed))


@settings(max_examples=8, deadline=None)
@given(s=st.integers(1, 70), h=st.sampled_from([1, 2]),
       seed=st.integers(0, 2**31 - 1), dtype=_DTYPE)
def test_ssd_property(s, h, seed, dtype):
    case = kh.Case("ssd_scan", f"prop-s{s}h{h}", dtype,
                   kh._ssd_case(1, s, h, 16, 8, 16, jnp.dtype(dtype)))
    kh.check_case(case, jax.random.PRNGKey(seed))
