"""Serving engine: grouped adapters, paged KV slots, continuous batching.

The engine's contract is EXACTNESS under batching: for any mix of tenants,
prompt lengths, and token budgets it must emit byte-identical token streams
to the naive one-request-at-a-time loop (``generate_naive`` — the shape of
the pre-engine ``launch/serve.py``, un-jitted per-token adapter apply and
all). Goldens in tests/golden/serve_tokens.json pin the streams themselves
against silent drift of both paths.
"""
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import adapters as nano
from repro.models import model as model_lib
from repro.models.vision_stub import num_patches
from repro.serving import (
    AdapterBank,
    AdapterCache,
    AdapterCacheMiss,
    KVSlotManager,
    Request,
    ServingEngine,
    checkpoint_adapter_loader,
    generate_naive,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "serve_tokens.json")

# vlm / sliding-window dense / ssm / hybrid (rg-lru + local attn) / enc-dec
ARCHS = ["llava-1.5-7b", "h2o-danube-1.8b", "mamba2-130m",
         "recurrentgemma-9b", "whisper-base"]


@functools.lru_cache(maxsize=8)
def _setup(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    backbone = model_lib.init_backbone(key, cfg)
    tenants = {}
    for i, t in enumerate(["alpha", "beta"]):
        ad = nano.init_nanoedge(jax.random.fold_in(key, 100 + i), cfg)
        ad = jax.tree.map(
            lambda a, j=i: jax.random.normal(
                jax.random.fold_in(key, 200 + 17 * j + a.size % 91),
                a.shape, a.dtype) * 0.05,
            ad)
        tenants[t] = ad
    return cfg, backbone, tenants


def _requests(cfg, spec):
    """spec: [(tenant, prompt_len, max_new_tokens), ...] — deterministic."""
    rng = np.random.default_rng(7)
    m = num_patches(cfg) if cfg.frontend_dim else 0
    reqs = []
    for i, (tn, L, mnt) in enumerate(spec):
        patches = (rng.standard_normal((m, cfg.frontend_dim)).astype(np.float32)
                   if cfg.frontend_dim else None)
        reqs.append(Request(
            rid=i, tenant=tn,
            prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
            patches=patches, max_new_tokens=mnt))
    return reqs


MIXED_SPEC = [("alpha", 5, 6), ("beta", 9, 4), (None, 3, 5),
              ("alpha", 12, 3), ("beta", 7, 6)]


# ---------------------------------------------------------------------------
# exactness: engine == naive loop, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_engine_matches_naive(arch):
    cfg, backbone, tenants = _setup(arch)
    reqs = _requests(cfg, MIXED_SPEC)
    eng = ServingEngine(cfg, backbone, max_slots=3, prefill_len=12,
                        max_new_tokens=8, adapter_loader=tenants.__getitem__)
    got = eng.run(reqs)
    ref = generate_naive(cfg, backbone, reqs, tenants)
    for r in reqs:
        assert got[r.rid].tokens == ref[r.rid].tokens, (
            f"{arch} rid={r.rid}: engine {got[r.rid].tokens} != "
            f"naive {ref[r.rid].tokens}")
    # the batching actually batched: >1 request per decode step on average
    assert eng.mean_occupancy() > 1.0
    # mixed-length traffic compiled exactly one prefill + one decode shape
    assert eng.stats["prefills"] == len(reqs)


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_short_prompt_below_conv_window(arch):
    """Prompts shorter than the causal-conv window (d_conv-1 / cw-1) must
    still produce a full zero-left-extended conv state — regression for the
    truncated-tail crash in the unpadded (naive) prefill path."""
    cfg, backbone, tenants = _setup(arch)
    reqs = _requests(cfg, [("alpha", 1, 4), ("beta", 2, 4), (None, 2, 4)])
    eng = ServingEngine(cfg, backbone, max_slots=3, prefill_len=8,
                        max_new_tokens=4, adapter_loader=tenants.__getitem__)
    got = eng.run(reqs)
    ref = generate_naive(cfg, backbone, reqs, tenants)
    for r in reqs:
        assert got[r.rid].tokens == ref[r.rid].tokens


def test_engine_tokens_golden():
    """Pin the llava token streams — catches any drift of engine OR naive."""
    cfg, backbone, tenants = _setup("llava-1.5-7b")
    reqs = _requests(cfg, MIXED_SPEC)
    eng = ServingEngine(cfg, backbone, max_slots=3, prefill_len=12,
                        max_new_tokens=8, adapter_loader=tenants.__getitem__)
    got = eng.run(reqs)
    with open(GOLDEN) as f:
        want = json.load(f)["llava-1.5-7b"]
    assert {str(r.rid): got[r.rid].tokens for r in reqs} == want


def test_engine_pallas_grouped_matches_ref_path():
    """The Pallas grouped kernel inside the jitted decode step (interpret
    mode) produces the same streams as the jnp reference path."""
    cfg, backbone, tenants = _setup("h2o-danube-1.8b")
    reqs = _requests(cfg, [("alpha", 4, 4), ("beta", 6, 4), (None, 5, 4)])
    runs = {}
    for use_pallas in (False, True):
        eng = ServingEngine(cfg, backbone, max_slots=3, prefill_len=8,
                            max_new_tokens=4,
                            adapter_loader=tenants.__getitem__,
                            use_pallas_grouped=use_pallas)
        runs[use_pallas] = eng.run(reqs)
    for r in reqs:
        assert runs[True][r.rid].tokens == runs[False][r.rid].tokens


@pytest.mark.smoke
def test_two_tenants_distinct_adapters_distinct_streams():
    """Two tenants, same prompt, different adapters: the streams differ from
    each other AND each matches its single-tenant (isolated) run."""
    cfg, backbone, tenants = _setup("h2o-danube-1.8b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    reqs = [Request(rid=0, tenant="alpha", prompt=prompt, max_new_tokens=6),
            Request(rid=1, tenant="beta", prompt=prompt, max_new_tokens=6)]

    def fresh():
        return ServingEngine(cfg, backbone, max_slots=2, prefill_len=8,
                             max_new_tokens=8,
                             adapter_loader=tenants.__getitem__)

    both = fresh().run(reqs)
    assert both[0].tokens != both[1].tokens, (
        "distinct adapters must steer distinct streams")
    solo_a = fresh().run([reqs[0]])
    solo_b = fresh().run([reqs[1]])
    assert both[0].tokens == solo_a[0].tokens
    assert both[1].tokens == solo_b[1].tokens


def test_engine_stop_token_and_budget():
    cfg, backbone, tenants = _setup("h2o-danube-1.8b")
    reqs = _requests(cfg, [("alpha", 5, 6)])
    free_run = ServingEngine(cfg, backbone, max_slots=2, prefill_len=8,
                             max_new_tokens=8,
                             adapter_loader=tenants.__getitem__).run(reqs)
    stop = free_run[0].tokens[2]
    eng = ServingEngine(cfg, backbone, max_slots=2, prefill_len=8,
                        max_new_tokens=8, stop_token=stop,
                        adapter_loader=tenants.__getitem__)
    stopped = eng.run(_requests(cfg, [("alpha", 5, 6)]))
    assert stopped[0].tokens == free_run[0].tokens[:3]
    assert len(free_run[0].tokens) == 6  # budget respected


def test_submit_rejects_overlong_prompt():
    cfg, backbone, _ = _setup("h2o-danube-1.8b")
    eng = ServingEngine(cfg, backbone, max_slots=1, prefill_len=4,
                        max_new_tokens=4)
    with pytest.raises(ValueError, match="prefill_len"):
        eng.submit(Request(rid=0, tenant=None,
                           prompt=np.zeros(9, np.int32), max_new_tokens=2))


def test_window_guard_rejects_pad_overflow():
    """Padded prefill longer than the attention window would let pad KV evict
    live ring entries — the engine must refuse to build."""
    cfg, backbone, _ = _setup("h2o-danube-1.8b")
    assert cfg.sliding_window is not None
    with pytest.raises(ValueError, match="window"):
        ServingEngine(cfg, backbone, max_slots=1,
                      prefill_len=cfg.sliding_window + 1, max_new_tokens=4)


# ---------------------------------------------------------------------------
# adapter bank / cache units
# ---------------------------------------------------------------------------

def _bank(n_slots):
    cfg = get_smoke_config("h2o-danube-1.8b")
    return cfg, AdapterBank(cfg, n_slots)


def _adapters(cfg, seed):
    ad = nano.init_nanoedge(jax.random.PRNGKey(seed), cfg)
    return jax.tree.map(lambda a: a + 0.01 * seed, ad)


def test_adapter_cache_lru_eviction_order():
    cfg, bank = _bank(2)
    loads = []

    def loader(t):
        loads.append(t)
        return _adapters(cfg, len(loads))

    cache = AdapterCache(bank, loader=loader)
    sa = cache.acquire("a"); cache.release("a")
    sb = cache.acquire("b"); cache.release("b")
    assert {sa, sb} == {0, 1}
    assert cache.acquire("a") == sa          # hit, no load
    cache.release("a")
    assert loads == ["a", "b"]
    cache.acquire("c"); cache.release("c")   # evicts b (a was touched later)
    assert "b" not in cache and "a" in cache
    assert cache.stats() == {"hits": 1, "misses": 3, "evictions": 1,
                             "resident": 2}


def test_adapter_cache_pinned_slots_never_evicted():
    cfg, bank = _bank(1)
    cache = AdapterCache(bank, loader=lambda t: _adapters(cfg, 1))
    cache.acquire("a")  # pinned (no release)
    with pytest.raises(AdapterCacheMiss, match="pinned"):
        cache.acquire("b")
    cache.release("a")
    assert cache.acquire("b") == 0  # now evictable


def test_adapter_cache_none_tenant_is_identity():
    cfg, bank = _bank(1)
    cache = AdapterCache(bank)
    assert cache.acquire(None) == -1
    cache.release(None)  # no-op


def test_adapter_cache_miss_without_loader():
    cfg, bank = _bank(1)
    with pytest.raises(AdapterCacheMiss, match="no loader"):
        AdapterCache(bank).acquire("ghost")


def test_adapter_bank_set_slot_validates():
    cfg, bank = _bank(2)
    with pytest.raises(IndexError):
        bank.set_slot(5, _adapters(cfg, 1))
    bad = {"text": {"down": np.zeros((3, 3)), "up": np.zeros((3, 3))}}
    with pytest.raises(ValueError, match="shape"):
        bank.set_slot(0, bad)


def test_checkpoint_adapter_loader_roundtrip(tmp_path):
    from repro.checkpoint import save_pytree

    cfg = get_smoke_config("h2o-danube-1.8b")
    ad = _adapters(cfg, 3)
    save_pytree(str(tmp_path / "tenant7.npz"), ad)
    loader = checkpoint_adapter_loader(cfg, str(tmp_path))
    got = loader("tenant7")
    for mod in ad:
        for k in ("down", "up"):
            np.testing.assert_array_equal(np.asarray(got[mod][k]),
                                          np.asarray(ad[mod][k]))


# ---------------------------------------------------------------------------
# kv slot manager units
# ---------------------------------------------------------------------------

def test_kv_slot_manager_alloc_free():
    cfg = get_smoke_config("h2o-danube-1.8b")
    mgr = KVSlotManager(cfg, n_slots=3, capacity=16, dtype=jnp.float32)
    assert [mgr.alloc(), mgr.alloc(), mgr.alloc()] == [0, 1, 2]
    assert mgr.alloc() is None
    mgr.free(1)
    with pytest.raises(ValueError, match="double free"):
        mgr.free(1)
    assert mgr.alloc() == 1  # deterministic lowest-first reuse
    assert mgr.n_free == 0
    assert mgr.pool_bytes() == 3 * mgr.page_bytes()


def test_kv_slot_manager_write_installs_page():
    cfg = get_smoke_config("h2o-danube-1.8b")
    mgr = KVSlotManager(cfg, n_slots=2, capacity=16, dtype=jnp.float32)
    page = jax.tree.map(
        lambda a: jnp.ones((1,) + a.shape[1:] if a.ndim == 1 else
                           a.shape[:1] + (1,) + a.shape[2:], a.dtype),
        jax.tree.map(lambda a: a[:, :1], mgr.state))
    mgr.write(1, page, start_pos=5)
    assert mgr.pos[1] == 5 and mgr.pos[0] == 0
    for leaf in jax.tree.leaves(mgr.state):
        assert np.all(np.asarray(leaf)[:, 1] == 1.0)
        assert np.all(np.asarray(leaf)[:, 0] == 0.0)
