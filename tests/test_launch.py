"""Launch layer: step factories lower+compile on a debug mesh; sharding specs
resolve for every arch; roofline HLO parsing extracts collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import InputShape
from repro.launch import sharding_rules as rules
from repro.launch import steps as steps_lib
from repro.launch.roofline import collective_bytes_from_hlo, model_flops_estimate
from repro.sharding import use_mesh


def _mesh():
    devs = np.array(jax.devices()).reshape(1, 1)
    return jax.sharding.Mesh(devs, ("data", "model"))


SHAPES = {
    "train": InputShape("t", "train", 32, 2),
    "prefill": InputShape("p", "prefill", 32, 2),
    "decode": InputShape("d", "decode", 32, 2),
}


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mamba2-130m", "grok-1-314b",
                                  "recurrentgemma-9b", "whisper-base", "qwen2-vl-72b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_steps_lower_and_compile(arch, kind):
    from repro.launch.dryrun import build_lowerable

    cfg = get_smoke_config(arch)
    mesh = _mesh()
    with use_mesh(mesh):
        jitted, args = build_lowerable(cfg, SHAPES[kind], mesh)
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    assert float(cost.get("flops", 0.0)) > 0


def test_param_shardings_cover_all_archs():
    mesh = _mesh()
    for arch in ("glm4-9b", "llama4-scout-17b-a16e", "internlm2-20b"):
        cfg = get_smoke_config(arch)
        backbone = steps_lib.backbone_specs(cfg)
        sh = rules.make_param_shardings(mesh, backbone)
        assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(backbone)


@pytest.mark.smoke
def test_collective_parse():
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %noise = f32[8]{0} add(%a, %b)
  %a2a = bf16[4,4]{1,0} all-to-all(%z)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 16 * 512 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["all-to-all"] == 16 * 2
    assert out["count"] == 3


def test_model_flops_estimate_moe_counts_active_only():
    from repro.configs import get_config

    cfg = get_config("grok-1-314b")
    sh = InputShape("t", "train", 4096, 256)
    est = model_flops_estimate(cfg, sh)
    # active params ~ 314B*(2/8 experts)+attn ≈ 90B; 6*N*D with D=1.05M tokens
    n_active = est / (6 * 4096 * 256)
    assert 5e10 < n_active < 1.5e11, n_active


def test_input_specs_decode_state_structure():
    cfg = get_smoke_config("recurrentgemma-9b")
    ins = steps_lib.input_specs(cfg, SHAPES["decode"])
    assert "state" in ins and "token" in ins and "pos" in ins
    leaves = jax.tree.leaves(ins["state"])
    assert all(hasattr(l, "shape") for l in leaves)


@pytest.mark.smoke
def test_exec_config_modes():
    cfg = get_smoke_config("glm4-9b")
    full = steps_lib.exec_config(cfg, SHAPES["prefill"], "full")
    assert full.attn_chunk == 1024 and full.scan_layers
    roof = steps_lib.exec_config(cfg, SHAPES["prefill"], "roofline")
    assert roof.attn_chunk is None and not roof.scan_layers
    over = steps_lib.exec_config(cfg, SHAPES["train"], "roofline", {"loss_chunk": 512})
    assert over.loss_chunk == 512
