"""Differential-test harness every Pallas kernel registers with.

One place defines, per kernel family:

  * the dtype × shape grid — exact block multiples, ragged tails, odd sizes,
    and block-boundary ±1 cases;
  * the kernel/ref pair to compare (kernels run ``interpret=True``);
  * gradient cases (``jax.grad`` of kernel vs ``jax.grad`` of ref) for the
    families with custom VJPs (lora, flash_attention);
  * the tolerance policy — ALL tolerance literals live in ``TOLERANCES`` /
    ``TOLERANCE_OVERRIDES`` below, nothing is scattered through test files.

Tolerance semantics: a comparison passes when

    |got − want| ≤ rtol·|want| + atol_scale·max(1, ‖want‖∞)

i.e. the absolute floor scales with the magnitude of the reference tensor.
For reductions with cancellation (attention outputs, SSD states) individual
elements can sit arbitrarily close to zero while every term is O(‖want‖),
so a scale-blind pointwise rtol is unattainable at f32 — the ∞-norm floor
is the criterion that actually measures kernel error. f32 is pinned at
1e-6, bf16 at 2e-2 (SSD bf16 at 5e-2: the chunked recurrence's exp/cumsum
chains lose more mantissa than one matmul).

Consumers: ``tests/test_kernel_harness.py`` parametrizes over
``all_cases()`` / ``all_grad_cases()``; ``benchmarks/kernel_bench.py --quick``
runs one case per family as its parity gate. Registering a new kernel means
adding a ``@register_kernel`` builder here — the test files pick it up
without edits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fisher_merge import ops as fm_ops, ref as fm_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.lora import ops as lora_ops, ref as lora_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

# --------------------------------------------------------------------------
# tolerance policy — the single source of truth
# --------------------------------------------------------------------------

TOLERANCES: Dict[str, Dict[str, float]] = {
    "float32": {"rtol": 1e-6, "atol_scale": 1e-6},
    "bfloat16": {"rtol": 2e-2, "atol_scale": 2e-2},
}

TOLERANCE_OVERRIDES: Dict[Tuple[str, str], Dict[str, float]] = {
    # chunked recurrence: longer exp/cumsum chains than a single matmul
    ("ssd_scan", "bfloat16"): {"rtol": 5e-2, "atol_scale": 5e-2},
    # vs the O(S) sequential recurrence the chunked ALGORITHM (ref and
    # kernel alike) differs by reassociation across whole chunks
    ("ssd_scan_vs_sequential", "float32"): {"rtol": 1e-4, "atol_scale": 1e-4},
    ("ssd_scan_vs_sequential", "bfloat16"): {"rtol": 5e-2, "atol_scale": 5e-2},
    # gradient chains double the depth of the forward reduction
    ("flash_attention_grad", "float32"): {"rtol": 2e-6, "atol_scale": 2e-6},
    ("flash_attention_grad", "bfloat16"): {"rtol": 3e-2, "atol_scale": 3e-2},
}

DTYPES = (jnp.float32, jnp.bfloat16)


def tol_for(kernel: str, dtype) -> Dict[str, float]:
    name = jnp.dtype(dtype).name
    return TOLERANCE_OVERRIDES.get((kernel, name), TOLERANCES[name])


def assert_close(got, want, *, kernel: str, dtype, err_msg: str = ""):
    """The harness comparison: scale-aware pointwise allclose (see module
    docstring for why the atol floor tracks ‖want‖∞)."""
    tol = tol_for(kernel, dtype)
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    scale = max(1.0, float(np.max(np.abs(w))) if w.size else 1.0)
    np.testing.assert_allclose(
        g, w, rtol=tol["rtol"], atol=tol["atol_scale"] * scale,
        err_msg=f"{kernel} [{jnp.dtype(dtype).name}] {err_msg}")


# --------------------------------------------------------------------------
# case registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Case:
    kernel: str
    label: str
    dtype_name: str
    # rng -> (got, want); built lazily so collection stays cheap
    run: Callable[[jax.Array], Tuple[jax.Array, jax.Array]]

    @property
    def id(self) -> str:
        return f"{self.kernel}-{self.label}-{self.dtype_name}"


@dataclass(frozen=True)
class GradCase:
    kernel: str
    label: str
    dtype_name: str
    # rng -> (kernel_grads tuple, ref_grads tuple)
    run: Callable[[jax.Array], Tuple[Tuple, Tuple]]

    @property
    def id(self) -> str:
        return f"{self.kernel}-grad-{self.label}-{self.dtype_name}"


_CASE_BUILDERS: Dict[str, Callable[[], List[Case]]] = {}
_GRAD_BUILDERS: Dict[str, Callable[[], List[GradCase]]] = {}


def register_kernel(name: str, *, grads: bool = False):
    def deco(fn):
        _CASE_BUILDERS[name] = fn
        return fn

    return deco


def register_grads(name: str):
    def deco(fn):
        _GRAD_BUILDERS[name] = fn
        return fn

    return deco


def kernel_families() -> Tuple[str, ...]:
    return tuple(sorted(_CASE_BUILDERS))


def all_cases() -> List[Case]:
    out: List[Case] = []
    for name in sorted(_CASE_BUILDERS):
        out.extend(_CASE_BUILDERS[name]())
    return out


def all_grad_cases() -> List[GradCase]:
    out: List[GradCase] = []
    for name in sorted(_GRAD_BUILDERS):
        out.extend(_GRAD_BUILDERS[name]())
    return out


def smoke_cases() -> List[Case]:
    """First case per family — the parity gate for scripts/smoke.sh and
    ``kernel_bench --quick``."""
    return [_CASE_BUILDERS[name]()[0] for name in sorted(_CASE_BUILDERS)]


def check_case(case: Case, rng) -> None:
    got, want = case.run(rng)
    assert_close(got, want, kernel=case.kernel, dtype=case.dtype_name,
                 err_msg=case.label)


def check_grad_case(case: GradCase, rng) -> None:
    gots, wants = case.run(rng)
    for i, (g, w) in enumerate(zip(gots, wants)):
        assert_close(g, w, kernel=f"{case.kernel}_grad", dtype=case.dtype_name,
                     err_msg=f"{case.label} arg{i}")


# --------------------------------------------------------------------------
# lora — fused NanoAdapter residual (block_t=32 grid: 31/32/33 are the
# block-boundary ±1 cases, 1 and 100 the degenerate/ragged ones)
# --------------------------------------------------------------------------

LORA_SHAPES = [
    # (t, d, rank, block_t)
    (32, 32, 4, 32),      # exact single block
    (31, 32, 4, 32),      # block boundary −1
    (33, 32, 4, 32),      # block boundary +1
    (1, 48, 8, 32),       # single row, odd d
    (100, 96, 8, 32),     # ragged tail over several blocks
    (64, 33, 1, 16),      # odd feature dim, rank 1
]


def _lora_case(t, d, r, bt, dtype):
    def run(rng):
        x = jax.random.normal(rng, (t, d), dtype)
        down = (jax.random.normal(jax.random.fold_in(rng, 1), (d, r)) * 0.05).astype(dtype)
        up = (jax.random.normal(jax.random.fold_in(rng, 2), (r, d)) * 0.05).astype(dtype)
        got = lora_ops.lora_residual(x, down, up, scale=2.0, block_t=bt, interpret=True)
        want = lora_ref.lora_residual(x, down, up, scale=2.0)
        return got, want

    return run


@register_kernel("lora")
def _lora_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for t, d, r, bt in LORA_SHAPES:
            out.append(Case("lora", f"t{t}d{d}r{r}bt{bt}", jnp.dtype(dtype).name,
                            _lora_case(t, d, r, bt, dtype)))
    return out


GROUPED_LORA_SHAPES = [
    # (t, d, rank, n_adapters, block_t)
    (16, 32, 4, 3, 16),   # exact block
    (15, 32, 4, 3, 16),   # boundary −1
    (17, 32, 4, 3, 16),   # boundary +1 (mixed-adapter tail block)
    (50, 48, 8, 5, 16),   # ragged + odd d
]


def _grouped_case(t, d, r, n, bt, dtype):
    def run(rng):
        x = jax.random.normal(rng, (t, d), dtype)
        down = (jax.random.normal(jax.random.fold_in(rng, 1), (n, d, r)) * 0.05).astype(dtype)
        up = (jax.random.normal(jax.random.fold_in(rng, 2), (n, r, d)) * 0.05).astype(dtype)
        idx = jax.random.randint(jax.random.fold_in(rng, 3), (t,), -1, n)  # incl. identity rows
        got = lora_ops.grouped_lora_residual(x, down, up, idx, scale=2.0,
                                             block_t=bt, interpret=True)
        want = lora_ref.grouped_lora_residual(x, down, up, idx, scale=2.0)
        return got, want

    return run


@register_kernel("grouped_lora")
def _grouped_lora_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for t, d, r, n, bt in GROUPED_LORA_SHAPES:
            out.append(Case("grouped_lora", f"t{t}d{d}r{r}n{n}bt{bt}",
                            jnp.dtype(dtype).name, _grouped_case(t, d, r, n, bt, dtype)))
    return out


@register_grads("lora")
def _lora_grad_cases() -> List[GradCase]:
    def make(t, d, r, bt, dtype):
        def run(rng):
            x = jax.random.normal(rng, (t, d), dtype)
            down = (jax.random.normal(jax.random.fold_in(rng, 1), (d, r)) * 0.05).astype(dtype)
            up = (jax.random.normal(jax.random.fold_in(rng, 2), (r, d)) * 0.05).astype(dtype)

            def lk(x, a, b):
                y = lora_ops.lora_residual(x, a, b, scale=2.0, block_t=bt, interpret=True)
                return jnp.sum(y.astype(jnp.float32) ** 2)

            def lr(x, a, b):
                y = lora_ref.lora_residual(x, a, b, scale=2.0)
                return jnp.sum(y.astype(jnp.float32) ** 2)

            return (jax.grad(lk, argnums=(0, 1, 2))(x, down, up),
                    jax.grad(lr, argnums=(0, 1, 2))(x, down, up))

        return run

    out = []
    for dtype in DTYPES:
        for t, d, r, bt in [(37, 48, 8, 16), (16, 32, 4, 16), (33, 32, 8, 32)]:
            out.append(GradCase("lora", f"t{t}d{d}r{r}", jnp.dtype(dtype).name,
                                make(t, d, r, bt, dtype)))
    return out


# --------------------------------------------------------------------------
# flash attention — block 16 grid: 15/16/17 are boundary ±1; plus GQA/MQA,
# sliding window, softcap, decode-style single query, bidirectional
# --------------------------------------------------------------------------

FLASH_SHAPES = [
    # (label, b, sq, sk, h, hkv, d, causal, window, softcap, bq, bk)
    ("exact", 1, 16, 16, 2, 2, 32, True, None, 0.0, 16, 16),
    ("bound-1", 1, 15, 15, 2, 2, 32, True, None, 0.0, 16, 16),
    ("bound+1", 1, 17, 17, 2, 2, 32, True, None, 0.0, 16, 16),
    ("gqa-ragged", 2, 24, 24, 4, 2, 32, True, None, 0.0, 16, 16),
    ("mqa-window", 1, 40, 40, 4, 1, 32, True, 8, 0.0, 16, 16),
    ("decode", 1, 1, 33, 2, 1, 32, True, None, 0.0, 16, 16),
    ("bidir", 1, 24, 24, 2, 2, 64, False, None, 0.0, 16, 16),
    ("softcap", 1, 32, 32, 2, 2, 32, True, None, 10.0, 16, 16),
]


def _flash_args(rng, b, sq, sk, h, hkv, d, dtype):
    q = jax.random.normal(rng, (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (b, sk, hkv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (b, sk, hkv, d), dtype)
    return q, k, v


def _flash_case(shape, dtype):
    _, b, sq, sk, h, hkv, d, causal, window, cap, bq, bk = shape

    def run(rng):
        q, k, v = _flash_args(rng, b, sq, sk, h, hkv, d, dtype)
        got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                     softcap=cap, block_q=bq, block_k=bk,
                                     interpret=True)
        want = fa_ref.attention(q, k, v, causal=causal, window=window, softcap=cap)
        return got, want

    return run


@register_kernel("flash_attention")
def _flash_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for shape in FLASH_SHAPES:
            out.append(Case("flash_attention", shape[0], jnp.dtype(dtype).name,
                            _flash_case(shape, dtype)))
    return out


@register_grads("flash_attention")
def _flash_grad_cases() -> List[GradCase]:
    def make(shape, dtype):
        _, b, sq, sk, h, hkv, d, causal, window, cap, bq, bk = shape

        def run(rng):
            q, k, v = _flash_args(rng, b, sq, sk, h, hkv, d, dtype)

            def lk(q, k, v):
                y = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                           softcap=cap, block_q=bq, block_k=bk,
                                           interpret=True)
                return jnp.sum(y.astype(jnp.float32) ** 2)

            def lr(q, k, v):
                y = fa_ref.attention(q, k, v, causal=causal, window=window, softcap=cap)
                return jnp.sum(y.astype(jnp.float32) ** 2)

            return (jax.grad(lk, argnums=(0, 1, 2))(q, k, v),
                    jax.grad(lr, argnums=(0, 1, 2))(q, k, v))

        return run

    picks = [FLASH_SHAPES[2], FLASH_SHAPES[3], FLASH_SHAPES[4],
             FLASH_SHAPES[5], FLASH_SHAPES[7]]
    out = []
    for dtype in DTYPES:
        for shape in picks:
            out.append(GradCase("flash_attention", shape[0], jnp.dtype(dtype).name,
                                make(shape, dtype)))
    return out


# --------------------------------------------------------------------------
# fisher merge — block_n=256 grid: 255/256/257 boundary ±1, 7 odd, K=1 edge
# --------------------------------------------------------------------------

FISHER_SHAPES = [
    # (k, n, block_n)
    (5, 256, 256),
    (5, 255, 256),
    (5, 257, 256),
    (1, 100, 64),
    (16, 7, 256),
    (3, 1000, 256),
]


def _fisher_case(k, n, bn, dtype):
    def run(rng):
        t = jax.random.normal(rng, (k, n), dtype)
        f = jax.random.uniform(jax.random.fold_in(rng, 1), (k, n), minval=0.01).astype(dtype)
        w = jax.random.uniform(jax.random.fold_in(rng, 2), (k,), minval=0.1)
        got = fm_ops.fisher_merge(t, f, w, block_n=bn, interpret=True)
        want = fm_ref.fisher_merge(t, f, w)
        return got, want

    return run


@register_kernel("fisher_merge")
def _fisher_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for k, n, bn in FISHER_SHAPES:
            out.append(Case("fisher_merge", f"k{k}n{n}bn{bn}", jnp.dtype(dtype).name,
                            _fisher_case(k, n, bn, dtype)))
    return out


def _fisher_stream_case(k, n, bn, dtype):
    """Streaming fold kernel: fold K clients one at a time, finalize, and
    compare against the materializing oracle."""

    def run(rng):
        t = jax.random.normal(rng, (k, n), dtype)
        f = jax.random.uniform(jax.random.fold_in(rng, 1), (k, n), minval=0.01).astype(dtype)
        w = jax.random.uniform(jax.random.fold_in(rng, 2), (k,), minval=0.1)
        num = jnp.zeros((n,), jnp.float32)
        den = jnp.zeros((n,), jnp.float32)
        for i in range(k):
            num, den = fm_ops.fisher_fold(num, den, t[i], f[i], w[i],
                                          block_n=bn, interpret=True)
        got = fm_ref.fisher_finalize(num, den, dtype=dtype)
        want = fm_ref.fisher_merge(t, f, w)
        return got, want

    return run


@register_kernel("fisher_merge_stream")
def _fisher_stream_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for k, n, bn in [(5, 256, 256), (5, 257, 256), (3, 100, 64), (1, 31, 16)]:
            out.append(Case("fisher_merge_stream", f"k{k}n{n}bn{bn}",
                            jnp.dtype(dtype).name, _fisher_stream_case(k, n, bn, dtype)))
    return out


# --------------------------------------------------------------------------
# ssd scan — chunk=16 grid: 15/16/17 boundary ±1; kernel vs the chunked
# oracle at the SAME chunk (tight), plus one case vs the O(S) sequential
# recurrence (algorithmic tolerance, see TOLERANCE_OVERRIDES)
# --------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (1, 16, 2, 16, 8, 16),
    (1, 15, 2, 16, 8, 16),
    (1, 17, 2, 16, 8, 16),
    (2, 100, 3, 32, 16, 32),
    (1, 64, 2, 33, 8, 16),   # odd head dim
]


def _ssd_args(rng, b, s, h, p, n, dtype):
    x = (jax.random.normal(rng, (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.random.uniform(jax.random.fold_in(rng, 1), (b, s, h),
                            minval=0.01, maxval=0.2).astype(dtype)
    A = -jax.random.uniform(jax.random.fold_in(rng, 2), (h,), minval=0.5, maxval=2.0)
    B = (jax.random.normal(jax.random.fold_in(rng, 3), (b, s, n)) * 0.3).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(rng, 4), (b, s, n)) * 0.3).astype(dtype)
    return x, dt, A, B, C


def _ssd_case(b, s, h, p, n, q, dtype):
    def run(rng):
        x, dt, A, B, C = _ssd_args(rng, b, s, h, p, n, dtype)
        got = ssd_ops.ssd(x, dt, A, B, C, chunk=q, interpret=True)
        want = ssd_ref.ssd_chunked(x, dt, A, B, C, q)
        return got, want

    return run


@register_kernel("ssd_scan")
def _ssd_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for b, s, h, p, n, q in SSD_SHAPES:
            out.append(Case("ssd_scan", f"b{b}s{s}h{h}p{p}n{n}q{q}",
                            jnp.dtype(dtype).name, _ssd_case(b, s, h, p, n, q, dtype)))
    return out


def _ssd_seq_case(b, s, h, p, n, q, dtype):
    def run(rng):
        x, dt, A, B, C = _ssd_args(rng, b, s, h, p, n, dtype)
        got = ssd_ops.ssd(x, dt, A, B, C, chunk=q, interpret=True)
        want = ssd_ref.ssd_reference_sequential(x, dt, A, B, C)
        return got, want

    return run


@register_kernel("ssd_scan_vs_sequential")
def _ssd_seq_cases() -> List[Case]:
    out = []
    for dtype in DTYPES:
        for b, s, h, p, n, q in [(1, 64, 2, 16, 8, 16), (2, 100, 2, 16, 8, 32)]:
            out.append(Case("ssd_scan_vs_sequential", f"b{b}s{s}q{q}",
                            jnp.dtype(dtype).name,
                            _ssd_seq_case(b, s, h, p, n, q, dtype)))
    return out
