"""Subprocess body for the 8-device sharded-engine tests.

The main test session runs on the real 1-CPU topology (tests/conftest.py),
and a forced multi-device topology must be set via XLA_FLAGS *before* jax
first initializes — so tests/test_sharded.py runs this file in a fresh
interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Prints one line per check: ``OK <name>`` or ``FAIL <name>: <detail>``, and
exits non-zero if anything failed. Checks:

  * all six paper strategies on ``engine="sharded"`` (D=8) against the
    committed golden (rel 1e-6 on losses / accuracy / comm bytes; adapter
    sq-norms at 2e-5 — squaring near-zero adapters doubles the relative
    error of the per-device XLA fusion differences)
  * an uneven cohort (K=5 on D=8 → padded rows) against ``engine="vmap"``:
    identical comm byte counts prove the padding rows move zero bytes and
    never enter aggregation
  * checkpoint/resume replay parity on the sharded engine
"""
import json
import os
import sys
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.core import HyperParams, run_federated
from repro.data import make_federated_data
from repro.utils import tree_sq_norm

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "strategy_parity.json")
STRATEGIES = ("fednano", "fednano_ef", "fedavg", "fedprox", "feddpa_f", "locft")

failures = []


def check(name, cond, detail=""):
    if cond:
        print(f"OK {name}")
    else:
        failures.append(name)
        print(f"FAIL {name}: {detail}")


def rel(a, b):
    return abs(a - b) / max(abs(a), abs(b), 1e-12)


def make(n_clients):
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, frontend_dim=32)
    train, evald, _ = make_federated_data(
        cfg, n_clients=n_clients, examples_per_client=16, alpha=1.0,
        batch_size=4, seq_len=16)
    return cfg, train, evald


def run(cfg, train, evald, strategy, rounds=2, **kw):
    hp = HyperParams(lr=5e-3, local_steps=2, fisher_batches=2)
    return run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                         strategy=strategy, rounds=rounds, hp=hp, **kw)


def main():
    check("device_count", jax.device_count() == 8,
          f"got {jax.device_count()} devices")

    with open(GOLDEN) as f:
        golden = json.load(f)

    # --- six-strategy golden parity, even cohort (K=4 on D=8: padded) ------
    cfg, train, evald = make(4)
    for strategy in STRATEGIES:
        res = run(cfg, train, evald, strategy, engine="sharded")
        want = golden[strategy]
        got_losses = [m["mean_loss"] for m in res.round_metrics]
        bad = []
        if any(rel(g, w) > 1e-6 for g, w in zip(got_losses, want["round_losses"])):
            bad.append(f"losses {got_losses} vs {want['round_losses']}")
        if rel(res.avg_accuracy, want["avg_accuracy"]) > 1e-6:
            bad.append(f"acc {res.avg_accuracy} vs {want['avg_accuracy']}")
        if {str(k): v for k, v in res.comm_totals.items()} != \
                {k: v for k, v in want["comm_totals"].items()}:
            bad.append(f"comm {res.comm_totals} vs {want['comm_totals']}")
        if rel(float(tree_sq_norm(res.server.global_adapters)),
               want["global_sq_norm"]) > 2e-5:
            bad.append("global_sq_norm")
        if rel(float(tree_sq_norm(res.clients[0].adapters)),
               want["client0_sq_norm"]) > 2e-5:
            bad.append("client0_sq_norm")
        check(f"golden:{strategy}", not bad, "; ".join(bad))

    # --- uneven cohort (K=5 on D=8): padding inert vs vmap ------------------
    cfg5, train5, evald5 = make(5)
    for strategy in ("fednano", "feddpa_f"):
        a = run(cfg5, train5, evald5, strategy, engine="vmap")
        b = run(cfg5, train5, evald5, strategy, engine="sharded")
        bad = []
        if a.comm_totals != b.comm_totals:
            bad.append(f"comm {a.comm_totals} vs {b.comm_totals} — padding "
                       "rows leaked into byte accounting")
        la = [m["mean_loss"] for m in a.round_metrics]
        lb = [m["mean_loss"] for m in b.round_metrics]
        if any(rel(x, y) > 1e-6 for x, y in zip(la, lb)):
            bad.append(f"losses {la} vs {lb}")
        if any(x["participants"] != y["participants"]
               for x, y in zip(a.round_metrics, b.round_metrics)):
            bad.append("participant counts differ — padding rows counted")
        if rel(a.avg_accuracy, b.avg_accuracy) > 1e-6:
            bad.append("accuracy")
        check(f"uneven:{strategy}", not bad, "; ".join(bad))

    # --- checkpoint/resume on the sharded engine ----------------------------
    with tempfile.TemporaryDirectory() as td:
        full = run(cfg, train, evald, "fednano", engine="sharded")
        ck = os.path.join(td, "state")
        run(cfg, train, evald, "fednano", engine="sharded",
            checkpoint_dir=ck, checkpoint_every=1, rounds=1)
        resumed = run_federated(
            jax.random.PRNGKey(0), cfg, train, evald, strategy="fednano",
            rounds=2, hp=HyperParams(lr=5e-3, local_steps=2, fisher_batches=2),
            engine="sharded", resume=ck)
        lf = [m["mean_loss"] for m in full.round_metrics]
        lr_ = [m["mean_loss"] for m in resumed.round_metrics]
        bad = []
        if any(rel(x, y) > 1e-6 for x, y in zip(lf, lr_)):
            bad.append(f"losses {lf} vs {lr_}")
        if rel(float(tree_sq_norm(full.server.global_adapters)),
               float(tree_sq_norm(resumed.server.global_adapters))) > 2e-5:
            bad.append("global_sq_norm")
        check("resume:sharded", not bad, "; ".join(bad))

    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
