"""Resume-equivalence harness: kill-and-resume must replay the original run.

The contract under test: a run checkpointed at round r and resumed to round
R produces the SAME metrics, comm totals, accuracies, and final parameters
as an uninterrupted R-round run (within 1e-6 — everything downstream of the
restore is the same jitted computation on the same floats). That holds
because every source of protocol randomness is a pure function of
(seed, round, cid) — see repro.strategies.sampling.round_key — and because
RunState persists *all* carried state: ServerOpt moments, per-client AdamW
moments (global and personal), FedDPA warmup counters, transform error
feedback, the CommLog, and the buffered engine's event queue.

Engine split:
  * sequential / vmap: the round loop body never reads ``rounds``, so
    literally running 3 rounds, saving, and resuming to 6 equals a 6-round
    run. Tested for every paper strategy.
  * buffered: stopping AT the merge cap leaves same-tick completions
    undrained (exit state != pass-through state), so replay-equivalent
    snapshots are the mid-run ones (checkpoint_every) — the test resumes
    from a full run's intermediate snapshot, which is byte-identical to the
    state a killed run would have left.

Failure injection rides the same determinism: the churn schedule is a pure
function of (failure seed, round, cid), so runs under dropout/crash repeat
exactly and comm accounting can be replayed analytically.
"""
import math
import os

import jax
import pytest

from repro.checkpoint import CheckpointError
from repro.configs import get_smoke_config
from repro.core import FailureModel, HyperParams, run_federated
from repro.data import make_federated_data
from repro.strategies import FixedSizeSampler, Int8EFQuant, TopKSparsify
from repro.strategies.server_opt import FedAdamOpt
from repro.utils import tree_allclose, tree_bytes, tree_sq_norm

PAPER_STRATEGIES = ("fednano", "fednano_ef", "fedavg", "fedprox",
                    "feddpa_f", "locft")
ROUNDS = 4
CUT = 2  # checkpoint/kill boundary


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    train, evald, _ = make_federated_data(
        cfg, n_clients=3, examples_per_client=8, alpha=100.0, batch_size=2,
        seq_len=8,
    )
    return cfg, train, evald


def _hp(**kw):
    kw.setdefault("lr", 5e-3)
    kw.setdefault("local_steps", 1)
    kw.setdefault("fisher_batches", 1)
    return HyperParams(**kw)


def assert_equivalent(full, resumed):
    """Every observable of the resumed run matches the uninterrupted one."""
    fl = [m["mean_loss"] for m in full.round_metrics]
    rl = [m["mean_loss"] for m in resumed.round_metrics]
    assert len(fl) == len(rl)
    for a, b in zip(fl, rl):
        if a is None or b is None:
            assert a is None and b is None
        else:
            assert b == pytest.approx(a, rel=1e-6)
    assert resumed.comm_totals == full.comm_totals
    assert resumed.avg_accuracy == pytest.approx(full.avg_accuracy, abs=1e-9)
    assert float(tree_sq_norm(resumed.server.global_adapters)) == pytest.approx(
        float(tree_sq_norm(full.server.global_adapters)), rel=1e-6)
    for cf, cr in zip(full.clients, resumed.clients):
        assert tree_allclose(cf.adapters, cr.adapters, atol=1e-6)
        assert cf.rounds_participated == cr.rounds_participated


def _kill_and_resume(setup, tmp_path, strategy, *, engine="sequential",
                     hp=None, **kw):
    """run CUT rounds + save → resume to ROUNDS; return (full, resumed)."""
    cfg, train, evald = setup
    hp = hp or _hp()
    key = jax.random.PRNGKey(0)
    d = str(tmp_path / "state")
    full = run_federated(key, cfg, train, evald, strategy=strategy,
                         rounds=ROUNDS, hp=hp, engine=engine, **kw)
    run_federated(key, cfg, train, evald, strategy=strategy, rounds=CUT,
                  hp=hp, engine=engine, checkpoint_dir=d, final_eval=False,
                  **kw)
    resumed = run_federated(key, cfg, train, evald, strategy=strategy,
                            rounds=ROUNDS, hp=hp, engine=engine, resume=d,
                            **kw)
    return full, resumed


# ---------------------------------------------------------------------------
# resume equivalence: every paper strategy, sequential engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", PAPER_STRATEGIES)
def test_resume_equivalence_sequential(setup, tmp_path, strategy):
    hp = _hp(dpa_warmup_rounds=1) if strategy == "feddpa_f" else _hp()
    full, resumed = _kill_and_resume(setup, tmp_path, strategy, hp=hp)
    assert_equivalent(full, resumed)


@pytest.mark.smoke
@pytest.mark.parametrize("strategy", ("fednano", "fedavg"))
def test_resume_equivalence_vmap(setup, tmp_path, strategy):
    full, resumed = _kill_and_resume(setup, tmp_path, strategy, engine="vmap")
    assert_equivalent(full, resumed)


@pytest.mark.parametrize("strategy", ("fednano", "fedavg"))
def test_resume_equivalence_buffered(setup, tmp_path, strategy):
    # buffered snapshots are replay-equivalent at tick boundaries mid-run:
    # resume from the full run's intermediate snapshot (== what a killed run
    # leaves behind) rather than from an exit-state snapshot
    cfg, train, evald = setup
    hp = _hp()
    key = jax.random.PRNGKey(0)
    d = str(tmp_path / "state")
    lat = lambda cid, version: 1 + (cid % 2)  # noqa: E731 — heterogeneous
    full = run_federated(key, cfg, train, evald, strategy=strategy,
                         rounds=ROUNDS, hp=hp, engine="buffered",
                         buffer_size=2, latency_fn=lat,
                         checkpoint_dir=d, checkpoint_every=CUT)
    resumed = run_federated(key, cfg, train, evald, strategy=strategy,
                            rounds=ROUNDS, hp=hp, engine="buffered",
                            buffer_size=2, latency_fn=lat,
                            resume=os.path.join(d, f"round_{CUT:06d}"))
    assert_equivalent(full, resumed)


# ---------------------------------------------------------------------------
# carried state actually survives: moments, warmup counters, residuals
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_resume_restores_server_opt_moments(setup, tmp_path):
    # FedAdam's m/v moments must come back: a resume that silently re-zeroed
    # them would still run (shapes match!) but take differently-sized steps
    full, resumed = _kill_and_resume(setup, tmp_path, "fedavg",
                                     server_opt=FedAdamOpt(lr=0.5))
    assert resumed.server_opt_state is not None
    assert set(resumed.server_opt_state) == {"m", "v"}
    assert tree_allclose(resumed.server_opt_state["m"],
                         full.server_opt_state["m"], atol=1e-6)
    assert_equivalent(full, resumed)


def test_resume_mid_warmup_feddpa(setup, tmp_path):
    # cut INSIDE the personal-adapter warmup window: rounds_participated and
    # local_opt_state must restore or the post-resume rounds would re-run
    # warmup (or skip it) on the wrong adapter
    hp = _hp(dpa_warmup_rounds=CUT + 1)
    full, resumed = _kill_and_resume(setup, tmp_path, "feddpa_f", hp=hp)
    assert_equivalent(full, resumed)
    for cf, cr in zip(full.clients, resumed.clients):
        assert tree_allclose(cf.local_adapters, cr.local_adapters, atol=1e-6)


@pytest.mark.parametrize("transform", [Int8EFQuant(), TopKSparsify(frac=0.25)],
                         ids=["int8_ef", "topk"])
def test_resume_restores_transform_residuals(setup, tmp_path, transform):
    # error-feedback residuals are carried client state: dropping them on
    # resume biases every subsequent quantized upload
    full, resumed = _kill_and_resume(setup, tmp_path, "fedavg",
                                     transforms=(transform,))
    assert_equivalent(full, resumed)


@pytest.mark.smoke
def test_resume_partial_participation(setup, tmp_path):
    # stateless sampler contract: the resumed run re-draws round r's cohort
    # from (seed, r) and gets the identical cohort the full run saw
    full, resumed = _kill_and_resume(setup, tmp_path, "fednano",
                                     sampler=FixedSizeSampler(n=2, seed=11))
    assert_equivalent(full, resumed)


# ---------------------------------------------------------------------------
# failure injection: deterministic churn, exact accounting
# ---------------------------------------------------------------------------

def test_failure_injection_finite_and_deterministic(setup):
    cfg, train, evald = setup
    hp = _hp()
    fm = FailureModel(dropout_prob=0.3, crash_prob=0.2, seed=7)
    key = jax.random.PRNGKey(0)
    runs = [run_federated(key, cfg, train, evald, strategy="fedavg",
                          rounds=ROUNDS, hp=hp, failures=fm)
            for _ in range(2)]
    for m in runs[0].round_metrics:
        assert m["mean_loss"] is None or math.isfinite(m["mean_loss"])
        assert m["participants"] + m["dropped"] + m["crashed"] == len(train)
    assert ([m["mean_loss"] for m in runs[0].round_metrics]
            == [m["mean_loss"] for m in runs[1].round_metrics])
    assert runs[0].comm_totals == runs[1].comm_totals


def test_failure_injection_exact_comm_accounting(setup):
    # replay the seeded churn schedule by hand and predict every byte:
    # dropped clients move nothing; crashed clients charge one download;
    # survivors charge a download and an upload
    cfg, train, evald = setup
    hp = _hp()
    fm = FailureModel(dropout_prob=0.3, crash_prob=0.2, seed=7)
    res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                        strategy="fedavg", rounds=ROUNDS, hp=hp, failures=fm)
    gbytes = tree_bytes(res.server.global_adapters)
    exp_down = exp_up = 0
    for r in range(ROUNDS):
        for cid in sorted(train):
            if fm.drops(cid, r):
                continue
            exp_down += gbytes          # fedavg always downloads
            if not fm.crashes(cid, r):
                exp_up += gbytes        # dense upload, same tree as global
    assert res.comm_totals["param_down"] == exp_down
    assert res.comm_totals["param_up"] == exp_up
    assert res.comm_totals["param_up_wire"] == exp_up


@pytest.mark.smoke
def test_resume_with_failures(setup, tmp_path):
    # churn schedule is (seed, round, cid)-pure: resume replays the same
    # dropouts/crashes the uninterrupted run saw
    fm = FailureModel(dropout_prob=0.3, crash_prob=0.1, seed=5)
    full, resumed = _kill_and_resume(setup, tmp_path, "fednano", failures=fm)
    assert_equivalent(full, resumed)


def test_buffered_with_failures_completes(setup):
    cfg, train, evald = setup
    fm = FailureModel(dropout_prob=0.2, crash_prob=0.1, straggler_prob=0.3,
                      straggler_ticks=2, seed=3)
    res = run_federated(jax.random.PRNGKey(0), cfg, train, evald,
                        strategy="fedavg", rounds=3, hp=_hp(),
                        engine="buffered", buffer_size=2, failures=fm)
    assert len(res.round_metrics) == 3
    assert all(math.isfinite(m["mean_loss"]) for m in res.round_metrics)
    assert all(math.isfinite(a) for a in res.client_accuracy.values())


# ---------------------------------------------------------------------------
# resume validation: a checkpoint can't silently replay the wrong run
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_resume_rejects_mismatched_run(setup, tmp_path):
    cfg, train, evald = setup
    hp = _hp()
    key = jax.random.PRNGKey(0)
    d = str(tmp_path / "state")
    run_federated(key, cfg, train, evald, strategy="fednano", rounds=1,
                  hp=hp, checkpoint_dir=d, final_eval=False)

    with pytest.raises(CheckpointError, match="strategy"):
        run_federated(key, cfg, train, evald, strategy="fedavg", rounds=2,
                      hp=hp, resume=d)
    with pytest.raises(CheckpointError, match="engine"):
        run_federated(key, cfg, train, evald, strategy="fednano", rounds=2,
                      hp=hp, engine="vmap", resume=d)
    with pytest.raises(CheckpointError, match="hyperparameters"):
        run_federated(key, cfg, train, evald, strategy="fednano", rounds=2,
                      hp=_hp(lr=1e-2), resume=d)
    with pytest.raises(CheckpointError, match="transform chain"):
        run_federated(key, cfg, train, evald, strategy="fednano", rounds=2,
                      hp=hp, transforms=(Int8EFQuant(),), resume=d)
    with pytest.raises(CheckpointError, match="PRNG key"):
        run_federated(jax.random.PRNGKey(1), cfg, train, evald,
                      strategy="fednano", rounds=2, hp=hp, resume=d)
