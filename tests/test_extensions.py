"""Beyond-paper extensions: int8 upload compression with error feedback,
rank-heterogeneous adapters, client-level DP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # property tests skip, unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.core import adapters as A
from repro.core.compression import (
    compress_update,
    dequantize_delta,
    init_error_feedback,
    quantize_delta,
)
from repro.core.hetero import (
    hetero_fisher_merge,
    pad_adapter,
    pad_nanoedge,
    truncate_nanoedge,
)
from repro.core.privacy import clip_by_global_norm, dp_sigma, privatize_update
from repro.utils import tree_allclose, tree_sub, tree_sq_norm


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded(rng):
    delta = {"w": jax.random.normal(rng, (64, 32)) * 0.1}
    q = quantize_delta(delta)
    recon = dequantize_delta(q)
    amax = float(jnp.max(jnp.abs(delta["w"])))
    err = float(jnp.max(jnp.abs(recon["w"] - delta["w"])))
    assert err <= amax / 127.0 + 1e-7  # half-step quantization bound
    assert q.wire_bytes < q.base_bytes / 3.9  # ~4x compression


def test_error_feedback_unbiased_over_rounds(rng):
    """Cumulative reconstructed delta converges to the cumulative true delta."""
    k = rng
    err = init_error_feedback({"w": jnp.zeros((32, 8))})
    global_ref = {"w": jnp.zeros((32, 8))}
    total_true = jnp.zeros((32, 8))
    total_recon = jnp.zeros((32, 8))
    for step in range(6):
        k = jax.random.fold_in(k, step)
        adapters = {"w": total_true + jax.random.normal(k, (32, 8)) * 0.05}
        true_delta = adapters["w"] - total_true
        q, err, recon = compress_update(adapters, {"w": total_true}, err)
        total_recon = total_recon + recon["w"]
        total_true = adapters["w"]
    # residual is bounded by one quantization step, not accumulated drift
    resid = float(jnp.max(jnp.abs(total_recon - total_true)))
    amax = float(jnp.max(jnp.abs(err["w"])))
    assert resid < 0.02, resid


@pytest.mark.smoke
def test_compression_wire_accounting(rng):
    delta = {"a": jnp.ones((100,)), "b": jnp.ones((10, 10))}
    q = quantize_delta(delta)
    assert q.base_bytes == 200 * 4
    assert q.wire_bytes == 200 * 1 + 2 * 4


# ---------------------------------------------------------------------------
# heterogeneous ranks
# ---------------------------------------------------------------------------

def _adapter(key, d, r, scale=0.1):
    k1, k2 = jax.random.split(key)
    return {
        "down": jax.random.normal(k1, (d, r)) * scale,
        "up": jax.random.normal(k2, (r, d)) * scale,
    }


def test_pad_preserves_adapter_function(rng):
    d, r, rmax = 16, 4, 8
    adp = _adapter(rng, d, r)
    padded = pad_adapter(adp, rmax)
    x = jax.random.normal(rng, (5, d))
    y1 = A.nano_adapter_apply(adp, x, rank=r, alpha=2.0 * r)
    # same alpha/rank SCALE must be used for the padded pair to be identical
    y2 = A.nano_adapter_apply(padded, x, rank=r, alpha=2.0 * r)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


def test_hetero_merge_shapes_and_zero_fisher_on_padding(rng):
    d = 16
    ranks = [2, 4, 8]
    thetas, fishers = [], []
    for i, r in enumerate(ranks):
        adp = {"text": _adapter(jax.random.fold_in(rng, i), d, r)}
        thetas.append(adp)
        fishers.append(jax.tree.map(lambda x: jnp.abs(x) + 0.1, adp))
    merged = hetero_fisher_merge(thetas, fishers, ranks)
    assert merged["text"]["down"].shape == (d, 8)
    assert merged["text"]["up"].shape == (8, d)
    # coordinates where ONLY the rank-8 client has mass equal its values
    np.testing.assert_allclose(
        np.asarray(merged["text"]["down"][:, 4:]),
        np.asarray(thetas[2]["text"]["down"][:, 4:]),
        rtol=1e-4, atol=1e-5,
    )


def test_truncate_roundtrip(rng):
    adp = {"text": _adapter(rng, 16, 8)}
    t = truncate_nanoedge(adp, 4)
    assert t["text"]["down"].shape == (16, 4)
    p = pad_nanoedge(t, 8)
    np.testing.assert_allclose(
        np.asarray(p["text"]["down"][:, :4]), np.asarray(adp["text"]["down"][:, :4])
    )


@settings(max_examples=15, deadline=None)
@given(r1=st.integers(1, 6), r2=st.integers(1, 6))
def test_hetero_merge_convex_hull(r1, r2):
    key = jax.random.PRNGKey(r1 * 7 + r2)
    d = 8
    rmax = max(r1, r2)
    t1 = {"text": _adapter(key, d, r1)}
    t2 = {"text": _adapter(jax.random.fold_in(key, 1), d, r2)}
    merged = hetero_fisher_merge([t1, t2], [None, None], [r1, r2])
    lo = jnp.minimum(
        pad_nanoedge(t1, rmax)["text"]["down"], pad_nanoedge(t2, rmax)["text"]["down"]
    )
    hi = jnp.maximum(
        pad_nanoedge(t1, rmax)["text"]["down"], pad_nanoedge(t2, rmax)["text"]["down"]
    )
    m = merged["text"]["down"]
    assert bool(jnp.all(m >= lo - 1e-4)) and bool(jnp.all(m <= hi + 1e-4))


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_clip_by_global_norm(rng):
    t = {"w": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(t, 1.0)
    assert abs(float(jnp.sqrt(tree_sq_norm(clipped))) - 1.0) < 1e-5
    small = {"w": jnp.full((10,), 0.01)}
    unclipped, _ = clip_by_global_norm(small, 1.0)
    assert tree_allclose(unclipped, small, rtol=1e-6)


def test_privatize_update_noise_scales(rng):
    ref = {"w": jnp.zeros((2000,))}
    adp = {"w": jnp.ones((2000,)) * 0.001}
    theta, info = privatize_update(rng, adp, ref, clip_norm=1.0, noise_mult=0.5)
    noise = tree_sub(theta, adp)
    std = float(jnp.std(noise["w"]))
    assert 0.4 < std < 0.6  # ≈ noise_mult * clip_norm
    theta0, _ = privatize_update(rng, adp, ref, clip_norm=1.0, noise_mult=0.0)
    assert tree_allclose(theta0, adp, rtol=1e-6)


@pytest.mark.smoke
def test_dp_sigma_monotone():
    assert dp_sigma(1.0, 1e-5) > dp_sigma(4.0, 1e-5)
    with pytest.raises(ValueError):
        dp_sigma(0.0, 1e-5)
