"""NanoAdapter / NanoEdge / Fisher-estimation unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import Batch, FisherAccumulator, adapters as A, fisher as F
from repro.utils import tree_allclose, tree_size


@pytest.mark.smoke
def test_adapter_identity_at_init(rng):
    """Zero-init up-projection => adapter is exact identity at round 0."""
    p = A.init_nano_adapter(rng, 32, 4)
    x = jax.random.normal(rng, (2, 5, 32))
    y = A.nano_adapter_apply(p, x, rank=4, alpha=8.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


@pytest.mark.smoke
def test_adapter_scale(rng):
    p = A.init_nano_adapter(rng, 16, 4)
    p["up"] = jax.random.normal(rng, (4, 16)) * 0.1
    x = jax.random.normal(rng, (3, 16))
    y8 = A.nano_adapter_apply(p, x, rank=4, alpha=8.0)
    y16 = A.nano_adapter_apply(p, x, rank=4, alpha=16.0)
    np.testing.assert_allclose(
        np.asarray(y16 - x), 2 * np.asarray(y8 - x), rtol=1e-4, atol=1e-6
    )


def test_adapter_pallas_matches_jnp(rng):
    p = A.init_nano_adapter(rng, 64, 8)
    p["up"] = jax.random.normal(rng, (8, 64)) * 0.1
    x = jax.random.normal(rng, (2, 10, 64))
    y1 = A.nano_adapter_apply(p, x, rank=8, alpha=16.0, use_pallas=False)
    y2 = A.nano_adapter_apply(p, x, rank=8, alpha=16.0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)


def test_nanoedge_param_count_matches_analytic(rng):
    cfg = get_smoke_config("llava-1.5-7b")
    adp = A.init_nanoedge(rng, cfg)
    assert tree_size(adp) == A.adapter_param_count(cfg)
    assert set(adp) == {"text", "image"}


def test_vlm_image_prefix_is_unsupervised(rng):
    cfg = get_smoke_config("llava-1.5-7b")
    from repro.models import model as M
    from repro.models.vision_stub import num_patches

    backbone = M.init_backbone(rng, cfg)
    adp = A.init_nanoedge(rng, cfg)
    b, s = 2, 12
    m = num_patches(cfg)
    batch = Batch(
        tokens=jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
        labels=jnp.zeros((b, s), jnp.int32),
        mask=jnp.ones((b, s), jnp.float32),
        patches=jax.random.normal(rng, (b, m, cfg.frontend_dim)),
    )
    embeds, positions, labels, mask, enc = A.nanoedge_forward(cfg, backbone, adp, batch)
    assert embeds.shape[1] == m + s
    assert float(jnp.sum(mask[:, :m])) == 0.0, "image prefix must be unsupervised"
    assert positions.shape == (b, m + s)


def test_fisher_accumulator(rng):
    params = {"a": jnp.zeros((3,))}
    acc = FisherAccumulator.init(params)
    g1 = {"a": jnp.array([1.0, 2.0, 3.0])}
    g2 = {"a": jnp.array([3.0, 0.0, 1.0])}
    acc = acc.update(g1).update(g2)
    fim = acc.finalize(eps=0.0)
    np.testing.assert_allclose(np.asarray(fim["a"]), [(1 + 9) / 2, 4 / 2, (9 + 1) / 2])


def test_fisher_pass_equals_mean_sq_grads(rng):
    def grad_fn(p, batch):
        return {"w": 2.0 * p["w"] * batch}

    p = {"w": jnp.array([1.0, -1.0])}
    batches = [jnp.float32(1.0), jnp.float32(2.0)]
    fim = F.fisher_pass(grad_fn, p, batches, eps=0.0)
    # grads: [2, -2] and [4, -4] -> mean sq = (4+16)/2 = 10
    np.testing.assert_allclose(np.asarray(fim["w"]), [10.0, 10.0])


def test_backbone_truly_frozen(rng):
    """grad of fednano_loss w.r.t. adapters must leave the backbone untouched
    AND produce zero cotangent for it if requested."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    from repro.models import model as M

    backbone = M.init_backbone(rng, cfg)
    adp = A.init_nanoedge(rng, cfg)
    batch = Batch(
        tokens=jax.random.randint(rng, (2, 8), 0, cfg.vocab_size),
        labels=jax.random.randint(rng, (2, 8), 0, cfg.vocab_size),
        mask=jnp.ones((2, 8), jnp.float32),
    )
    before = jax.tree.map(jnp.copy, backbone)
    loss, grads = jax.value_and_grad(
        lambda a: A.fednano_loss(cfg, backbone, a, batch)[0]
    )(adp)
    assert tree_allclose(backbone, before)
    assert set(grads) == set(adp)
