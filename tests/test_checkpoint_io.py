"""Checkpoint IO: strict restore semantics, versioning, and the golden
RunState layout.

The restore contract is *strict by default*: missing keys, extra keys,
shape drift, and dtype drift are all errors — never silent casts or
half-restores. A checkpoint saved at a different precision (or by a
different format version) must be converted deliberately; loading it
through an implicit cast corrupts optimizer moments without a single
visible symptom.

The golden fixture under ``tests/golden/run_state/`` (regenerate with
``scripts/gen_runstate_golden.py``) pins the on-disk layout: npz key paths,
meta.json fields, and leaf values. If this file's tests fail after a format
change, bump ``RUN_STATE_VERSION`` and regenerate — loudly, on purpose.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    CheckpointVersionError,
    RUN_STATE_VERSION,
    SERVER_CHECKPOINT_VERSION,
    load_pytree,
    load_run_state,
    load_server_checkpoint,
    read_run_meta,
    resolve_run_state_dir,
    save_pytree,
    save_run_state,
    save_server_checkpoint,
)
from repro.utils import tree_allclose

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden", "run_state")


# ---------------------------------------------------------------------------
# pytree <-> npz edge cases
# ---------------------------------------------------------------------------

@pytest.mark.smoke
def test_empty_pytree_roundtrip(tmp_path):
    p = str(tmp_path / "empty.npz")
    save_pytree(p, {})
    assert load_pytree(p, {}) == {}


@pytest.mark.smoke
def test_scalar_leaves_roundtrip(tmp_path):
    tree = {"a": jnp.float32(1.5), "b": jnp.int32(3),
            "nested": {"c": jnp.zeros(())}}
    p = str(tmp_path / "scalars.npz")
    save_pytree(p, tree)
    back = load_pytree(p, jax.tree.map(jnp.zeros_like, tree))
    assert float(back["a"]) == 1.5
    assert int(back["b"]) == 3
    assert back["b"].dtype == jnp.int32
    assert back["nested"]["c"].shape == ()


def test_missing_key_errors(tmp_path):
    p = str(tmp_path / "t.npz")
    save_pytree(p, {"a": jnp.ones(3)})
    with pytest.raises(CheckpointError, match="missing key"):
        load_pytree(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_extra_key_errors_unless_lenient(tmp_path):
    p = str(tmp_path / "t.npz")
    save_pytree(p, {"a": jnp.ones(3), "b": jnp.ones(2)})
    with pytest.raises(CheckpointError, match="keys not in the reference"):
        load_pytree(p, {"a": jnp.ones(3)})
    back = load_pytree(p, {"a": jnp.zeros(3)}, strict=False)
    assert tree_allclose(back, {"a": jnp.ones(3)})


def test_shape_mismatch_errors(tmp_path):
    p = str(tmp_path / "t.npz")
    save_pytree(p, {"a": jnp.ones((2, 3))})
    with pytest.raises(CheckpointError, match="shape mismatch"):
        load_pytree(p, {"a": jnp.ones((3, 2))})


@pytest.mark.smoke
def test_dtype_mismatch_errors_not_casts(tmp_path):
    # the satellite fix: a float32 checkpoint restored into a float16
    # reference used to cast silently — now it refuses
    p = str(tmp_path / "t.npz")
    save_pytree(p, {"a": jnp.ones(4, dtype=jnp.float32)})
    with pytest.raises(CheckpointError, match="dtype mismatch"):
        load_pytree(p, {"a": jnp.ones(4, dtype=jnp.float16)})


# ---------------------------------------------------------------------------
# server checkpoints: v2 carries what v1 dropped
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_server():
    from repro.configs import get_smoke_config
    from repro.core import server as server_lib

    cfg = get_smoke_config("llava-1.5-7b").with_(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, frontend_dim=16,
    )
    return server_lib.init_server(jax.random.PRNGKey(0), cfg)


def test_server_checkpoint_preserves_opt_moments_and_rng(tmp_path, tiny_server):
    from repro.strategies.server_opt import FedAdamOpt

    opt = FedAdamOpt()
    moments = jax.tree.map(lambda x: jnp.full_like(x, 0.5),
                           opt.init(tiny_server.global_adapters))
    key = jax.random.PRNGKey(42)
    d = str(tmp_path / "ckpt")
    save_server_checkpoint(d, tiny_server, round_idx=3,
                           server_opt_state=moments, rng_key=key)
    restored, meta = load_server_checkpoint(
        d, tiny_server, server_opt_state=opt.init(tiny_server.global_adapters))
    assert meta["round_idx"] == 3
    assert tree_allclose(meta["server_opt_state"], moments)
    assert np.array_equal(meta["rng_key"], np.asarray(key))
    assert tree_allclose(restored.global_adapters, tiny_server.global_adapters)


def test_server_checkpoint_refuses_to_drop_moments(tmp_path, tiny_server):
    moments = {"m": jax.tree.map(jnp.zeros_like, tiny_server.global_adapters)}
    d = str(tmp_path / "ckpt")
    save_server_checkpoint(d, tiny_server, round_idx=1,
                           server_opt_state=moments)
    with pytest.raises(CheckpointError, match="ServerOpt moments"):
        load_server_checkpoint(d, tiny_server)


@pytest.mark.smoke
def test_server_checkpoint_version_mismatch(tmp_path, tiny_server):
    d = str(tmp_path / "ckpt")
    save_server_checkpoint(d, tiny_server, round_idx=1)
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format_version"] = SERVER_CHECKPOINT_VERSION - 1
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointVersionError, match="format_version"):
        load_server_checkpoint(d, tiny_server)


# ---------------------------------------------------------------------------
# RunState: torn writes, version checks, LATEST resolution, golden layout
# ---------------------------------------------------------------------------

def _golden_refs():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "gen_runstate_golden",
        os.path.join(os.path.dirname(__file__), "..", "scripts",
                     "gen_runstate_golden.py"))
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)

    import dataclasses

    def zeroed(c):  # ClientState is not a pytree node; zero per field
        return dataclasses.replace(
            c,
            adapters=jax.tree.map(jnp.zeros_like, c.adapters),
            opt_state=jax.tree.map(jnp.zeros_like, c.opt_state),
            fisher=(jax.tree.map(jnp.zeros_like, c.fisher)
                    if c.fisher is not None else None),
        )

    rs = gen.build()
    return rs, {
        "clients_ref": [zeroed(c) for c in rs.clients],
        "global_ref": jax.tree.map(jnp.zeros_like, rs.global_adapters),
        "transform_templates": [jax.tree.map(jnp.zeros_like,
                                             rs.global_adapters)],
    }


def test_golden_run_state_layout_pinned():
    # the committed fixture must load with today's code and carry exactly
    # the documented npz paths — renames/additions are format changes
    want_keys = {
        "__nonce__", "rng_key",
        "global/layer0/A", "global/layer0/B",
        "client/0/adapters/layer0/A", "client/0/adapters/layer0/B",
        "client/0/opt/mu/layer0/A", "client/0/opt/mu/layer0/B",
        "client/0/opt/nu/layer0/A", "client/0/opt/nu/layer0/B",
        "client/0/opt/step",
        "client/0/fisher/layer0/A", "client/0/fisher/layer0/B",
        "client/1/adapters/layer0/A", "client/1/adapters/layer0/B",
        "client/1/opt/mu/layer0/A", "client/1/opt/mu/layer0/B",
        "client/1/opt/nu/layer0/A", "client/1/opt/nu/layer0/B",
        "client/1/opt/step",
        "tstate/0/0/layer0/A", "tstate/0/0/layer0/B",
    }
    data = np.load(os.path.join(GOLDEN_DIR, "run_state.npz"))
    assert set(data.files) == want_keys

    meta = read_run_meta(GOLDEN_DIR)
    assert meta["format_version"] == RUN_STATE_VERSION
    assert meta["engine"] == "sequential"
    assert meta["strategy"] == "fedavg"
    assert meta["round_idx"] == 2
    assert meta["cfg_name"] == "golden-fixture"
    assert meta["tstate_present"] == [[True], [False]]

    want, refs = _golden_refs()
    rs = load_run_state(GOLDEN_DIR, **refs)
    assert tree_allclose(rs.global_adapters, want.global_adapters)
    for got, exp in zip(rs.clients, want.clients):
        assert got.cid == exp.cid
        assert got.rounds_participated == exp.rounds_participated
        assert tree_allclose(got.adapters, exp.adapters)
        assert tree_allclose(got.opt_state.mu, exp.opt_state.mu)
    assert rs.clients[0].fisher is not None
    assert rs.clients[1].fisher is None
    assert rs.comm_rounds == want.comm_rounds
    assert rs.round_metrics == want.round_metrics


def test_run_state_torn_write_detected(tmp_path):
    want, refs = _golden_refs()
    d = str(tmp_path / "rs")
    save_run_state(d, want)
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    # simulate a crash between the npz and meta.json of DIFFERENT saves
    meta["nonce"] = "sequential:99:99:0"
    meta["round_idx"] = 99
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointError, match="torn checkpoint"):
        load_run_state(d, **refs)


def test_run_state_version_mismatch(tmp_path):
    want, _ = _golden_refs()
    d = str(tmp_path / "rs")
    save_run_state(d, want)
    meta_path = os.path.join(d, "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["format_version"] = RUN_STATE_VERSION + 1
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointVersionError):
        read_run_meta(d)


@pytest.mark.smoke
def test_resolve_run_state_dir(tmp_path):
    want, _ = _golden_refs()
    root = str(tmp_path / "ckpts")
    sub = os.path.join(root, "round_000002")
    save_run_state(sub, want)
    with open(os.path.join(root, "LATEST"), "w") as f:
        f.write("round_000002")
    assert resolve_run_state_dir(root) == sub       # via LATEST
    assert resolve_run_state_dir(sub) == sub        # direct
    with pytest.raises(CheckpointError, match="no run-state checkpoint"):
        resolve_run_state_dir(str(tmp_path / "nowhere"))


def test_run_state_client_count_mismatch(tmp_path):
    want, refs = _golden_refs()
    d = str(tmp_path / "rs")
    save_run_state(d, want)
    refs["clients_ref"] = refs["clients_ref"][:1]
    with pytest.raises(CheckpointError, match="clients"):
        load_run_state(d, **refs)
