"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned archs (+ the paper's two): instantiate the
REDUCED same-family variant (≤2 layers, d_model ≤ 512, ≤4 experts) and run
one forward and one FedNano train step on CPU, asserting output shapes and
no NaNs. The FULL configs are exercised by the dry-run only.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_smoke_config
from repro.core import Batch, adapters as adapters_lib
from repro.models import model as M
from repro.models import vision_stub
from repro.optim import adamw_init, adamw_update

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((b, s), jnp.float32)
    patches = None
    if cfg.frontend_dim:
        m = cfg.enc_seq_len if cfg.family == "audio" else vision_stub.num_patches(cfg)
        patches = jax.random.normal(key, (b, m, cfg.frontend_dim))
    return Batch(tokens=tokens, labels=labels, mask=mask, patches=patches)


@pytest.mark.parametrize("arch", ALL)
@pytest.mark.smoke
def test_reduced_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 3  # hybrid smoke keeps one full (rec,rec,attn) triple
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_smoke_config(arch)
    params = M.init_backbone(rng, cfg)
    batch = _batch(cfg, rng)
    adapters = adapters_lib.init_nanoedge(rng, cfg)
    embeds, positions, labels, mask, enc = adapters_lib.nanoedge_forward(
        cfg, params, adapters, batch
    )
    hidden, aux = M.forward(cfg, params, embeds, positions, enc)
    lg = M.logits(cfg, params, hidden)
    b, s = batch.tokens.shape
    s_total = embeds.shape[1]
    assert hidden.shape == (b, s_total, cfg.d_model)
    assert lg.shape == (b, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any()), "NaN in logits"
    assert jnp.isfinite(jnp.asarray(aux)), "non-finite aux loss"


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch, rng):
    """One FedNano step: loss finite, adapters move, backbone frozen."""
    cfg = get_smoke_config(arch)
    params = M.init_backbone(rng, cfg)
    batch = _batch(cfg, rng)
    adapters = adapters_lib.init_nanoedge(rng, cfg)

    def loss_fn(adp):
        loss, _ = adapters_lib.fednano_loss(cfg, params, adp, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(adapters)
    assert jnp.isfinite(loss), f"loss={loss}"
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0, "adapters received no gradient"

    opt = adamw_init(adapters)
    new_adapters, _ = adamw_update(grads, opt, adapters, lr=1e-3)
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(adapters), jax.tree.leaves(new_adapters))
    )
    assert moved, "adapter params did not update"
