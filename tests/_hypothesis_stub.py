"""Fallback shims for environments without ``hypothesis``.

The property-based tests decorate with ``@given``/``@settings`` at module
scope, so a missing hypothesis kills *collection* of the whole module (and,
under ``-x``, the whole run). Importing these stand-ins instead marks just
the property tests as skipped while the plain unit tests keep running.
"""
import pytest


class _StrategyNamespace:
    """Stands in for ``hypothesis.strategies``: any call returns None."""

    def __getattr__(self, name):
        def _strategy(*args, **kwargs):
            return None

        return _strategy


st = _StrategyNamespace()


def given(*args, **kwargs):
    def deco(fn):
        @pytest.mark.skip(reason="hypothesis not installed")
        def _skipped():
            pass

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn

    return deco
